//! Real multi-process cluster execution: a [`ProcCluster`] frontend
//! drives `specdfa worker` **processes** over the [`super::proto`]
//! frame protocol, replacing the timing model of [`super::cloud`] with
//! actual sockets, actual crashes and actual recovery.
//!
//! ```text
//!   ProcCluster ──spawn──▶ specdfa worker (× N, Unix/TCP sockets)
//!        │   Hello(rate)◀──┘  §4.1 profile_host run *in-process*
//!        │
//!   match_bytes(pattern, input)
//!        │ 1. heartbeat sweep: dead workers leave the partition
//!        │ 2. Eq. (1) capacity weights → partition() → one chunk per
//!        │    live worker
//!        │ 3. Match frames fan out; workers stream Checkpoint
//!        │    progress frames and finish with Result (an identity-
//!        │    seeded L-vector covering the whole chunk)
//!        │ 4. failed chunks retry with exponential backoff on a
//!        │    survivor, resuming from the victim's last streamed
//!        │    checkpoint (match_chunk_states_resume — no rescan)
//!        │ 5. per-chunk L-vectors compose in order (Fig. 9 / Eq. 9);
//!        │    entry q0 of the composition is the sequential verdict
//!        ▼
//!   Outcome (EngineKind::Cluster)  — or, when the cluster is gone,
//!   the in-process Engine::Auto verdict (degraded, never an error)
//! ```
//!
//! **Degradation ladder** (every rung still returns the
//! `Engine::Sequential` verdict):
//!
//! 1. all workers healthy → full capacity-weighted fan-out;
//! 2. some workers dead → partition over the survivors;
//! 3. a chunk fails mid-flight → retry/backoff on a survivor, resumed
//!    from its last checkpoint (`ClusterStats::failovers`,
//!    `ClusterStats::resumed_bytes`);
//! 4. retry budget exhausted or no live workers → in-process
//!    `Engine::Auto` match (`ClusterStats::degraded`).
//!
//! Failure detection is deliberately *pessimistic*: any protocol
//! hiccup on a connection (timeout, EOF, bad frame, wrong offset)
//! marks that worker dead and it is never reused — correctness never
//! depends on guessing how broken a broken peer is.  Fault injection
//! ([`super::fault::FaultPlan`]) rides into each worker on its command
//! line, so every rung of the ladder is exercised deterministically in
//! CI.

use std::collections::HashMap;
use std::fmt;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::engine::outcome::{Detail, EngineKind, Outcome};
use crate::engine::stream::{Checkpoint, StreamMatcher};
use crate::engine::{
    CompiledMatcher, Engine, ExecPolicy, Matcher, Pattern,
};
use crate::speculative::lvector::LVector;
use crate::speculative::partition::partition;
use crate::speculative::profile::{profile_host, weights_from_capacities};

use super::fault::{parse_cluster_spec, Action, FaultPlan, Injector};
use super::proto::{self, Frame};

// ---------------------------------------------------------------------
// transport
// ---------------------------------------------------------------------

/// Which socket family the cluster runs over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    /// `AF_UNIX` stream sockets (unix hosts only).
    Unix,
    /// Loopback TCP (`127.0.0.1`), portable everywhere.
    Tcp,
}

impl Transport {
    /// Unix sockets where available, TCP elsewhere.
    pub fn default_for_host() -> Transport {
        if cfg!(unix) {
            Transport::Unix
        } else {
            Transport::Tcp
        }
    }
}

#[cfg(unix)]
static SOCKET_SEQ: AtomicU64 = AtomicU64::new(0);

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    fn bind(transport: Transport) -> Result<(Listener, String)> {
        match transport {
            Transport::Tcp => {
                let l = TcpListener::bind("127.0.0.1:0")
                    .context("bind cluster TCP listener")?;
                let addr = format!("tcp:{}", l.local_addr()?);
                Ok((Listener::Tcp(l), addr))
            }
            #[cfg(unix)]
            Transport::Unix => {
                let path = std::env::temp_dir().join(format!(
                    "specdfa-{}-{}.sock",
                    std::process::id(),
                    SOCKET_SEQ.fetch_add(1, Ordering::Relaxed),
                ));
                let _ = std::fs::remove_file(&path);
                let l = UnixListener::bind(&path)
                    .context("bind cluster unix listener")?;
                let addr = format!("unix:{}", path.display());
                Ok((Listener::Unix(l, path), addr))
            }
            #[cfg(not(unix))]
            Transport::Unix => {
                bail!("unix sockets are not available on this host")
            }
        }
    }

    /// Accept one connection, polling until `deadline`.
    fn accept_by(&self, deadline: Instant) -> Result<Conn> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true)?,
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(true)?,
        }
        loop {
            let res = match self {
                Listener::Tcp(l) => l.accept().map(|(s, _)| {
                    let _ = s.set_nodelay(true);
                    Conn::Tcp(s)
                }),
                #[cfg(unix)]
                Listener::Unix(l, _) => {
                    l.accept().map(|(s, _)| Conn::Unix(s))
                }
            };
            match res {
                Ok(conn) => {
                    conn.set_nonblocking(false)?;
                    return Ok(conn);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!("timed out waiting for a worker to attach");
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// One live socket to a worker (either family), with uniform timeout
/// control.
pub enum Conn {
    /// loopback TCP stream
    Tcp(TcpStream),
    /// unix-domain stream
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    fn set_nonblocking(&self, on: bool) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(on),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(on),
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// Connect to a frontend address of the form `tcp:HOST:PORT` or
/// `unix:PATH` (the string a [`ProcCluster`] passed to the spawned
/// worker's `--connect` flag).
pub fn connect(addr: &str) -> Result<Conn> {
    if let Some(hostport) = addr.strip_prefix("tcp:") {
        let s = TcpStream::connect(hostport)
            .with_context(|| format!("connect {addr}"))?;
        let _ = s.set_nodelay(true);
        return Ok(Conn::Tcp(s));
    }
    #[cfg(unix)]
    if let Some(path) = addr.strip_prefix("unix:") {
        let s = UnixStream::connect(path)
            .with_context(|| format!("connect {addr}"))?;
        return Ok(Conn::Unix(s));
    }
    bail!("unsupported cluster address {addr:?} (want tcp:… or unix:…)")
}

// ---------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------

/// Configuration of one `specdfa worker` process (parsed from its
/// command line by `cmd_worker`).
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// frontend address (`tcp:…` / `unix:…`)
    pub addr: String,
    /// worker index announced in the `Hello` frame
    pub id: u32,
    /// deterministic failure script for this process
    pub fault: FaultPlan,
    /// §4.1 profiling runs at startup
    pub profile_runs: usize,
    /// symbols per profiling run
    pub profile_sample_syms: usize,
}

impl Default for WorkerConfig {
    fn default() -> WorkerConfig {
        let proc = ProcConfig::default();
        WorkerConfig {
            addr: String::new(),
            id: 0,
            fault: FaultPlan::default(),
            profile_runs: proc.profile_runs,
            profile_sample_syms: proc.profile_sample_syms,
        }
    }
}

/// Run the worker side of the protocol until the frontend shuts the
/// connection (or the fault plan kills the process).  This is the body
/// of the `specdfa worker` subcommand.
pub fn run_worker(cfg: WorkerConfig) -> Result<()> {
    let mut conn = connect(&cfg.addr)?;
    let profile = profile_host(cfg.profile_runs, cfg.profile_sample_syms);
    let mut inj = Injector::new(cfg.fault);
    if worker_send(
        &mut conn,
        &mut inj,
        Frame::Hello {
            worker: cfg.id,
            rate_syms_per_us: profile.syms_per_us,
        },
    )
    .is_err()
    {
        return Ok(()); // frontend already gone
    }
    let mut patterns: HashMap<u32, CompiledMatcher> = HashMap::new();
    let mut bytes_matched = 0u64;
    loop {
        let frame = match proto::read_frame(&mut conn) {
            Ok(frame) => frame,
            Err(_) => return Ok(()), // EOF / frontend died: exit cleanly
        };
        let reply = match frame {
            Frame::Compile { pattern_id, pattern } => {
                match CompiledMatcher::compile(
                    &pattern,
                    Engine::Auto,
                    ExecPolicy::default(),
                ) {
                    Ok(cm) => {
                        let states = cm.dfa().num_states;
                        patterns.insert(pattern_id, cm);
                        Some(Frame::CompileOk { pattern_id, states })
                    }
                    Err(e) => Some(Frame::Error {
                        req_id: 0,
                        message: format!("compile failed: {e:#}"),
                    }),
                }
            }
            Frame::Match {
                req_id,
                pattern_id,
                checkpoint_every,
                resume,
                data,
            } => {
                serve_chunk(
                    &mut conn,
                    &mut inj,
                    &patterns,
                    ChunkJob {
                        req_id,
                        pattern_id,
                        checkpoint_every,
                        resume,
                        data,
                    },
                    &mut bytes_matched,
                )?;
                None
            }
            Frame::Heartbeat { nonce } => {
                if inj.stall_heartbeats() {
                    None // swallow the probe: the stall fault
                } else {
                    Some(Frame::Heartbeat { nonce })
                }
            }
            Frame::Shutdown => return Ok(()),
            other => Some(Frame::Error {
                req_id: 0,
                message: format!(
                    "unexpected {} frame on a worker",
                    other.kind().name()
                ),
            }),
        };
        if let Some(frame) = reply {
            if worker_send(&mut conn, &mut inj, frame).is_err() {
                return Ok(());
            }
        }
    }
}

struct ChunkJob {
    req_id: u64,
    pattern_id: u32,
    checkpoint_every: u64,
    resume: Option<Vec<u8>>,
    data: Vec<u8>,
}

/// Serve one `Match` frame: stream the chunk through an
/// identity-seeded [`StreamMatcher`] (or resume a shipped checkpoint),
/// emitting `Checkpoint` progress frames every `checkpoint_every`
/// bytes and a final fully-folded `Result`.
fn serve_chunk(
    conn: &mut Conn,
    inj: &mut Injector,
    patterns: &HashMap<u32, CompiledMatcher>,
    job: ChunkJob,
    bytes_matched: &mut u64,
) -> Result<()> {
    let Some(cm) = patterns.get(&job.pattern_id) else {
        worker_send(
            conn,
            inj,
            Frame::Error {
                req_id: job.req_id,
                message: format!("unknown pattern id {}", job.pattern_id),
            },
        )?;
        return Ok(());
    };
    let mut sm = match &job.resume {
        Some(bytes) => {
            match Checkpoint::from_bytes(bytes)
                .and_then(|c| StreamMatcher::from_checkpoint(cm, c))
            {
                Ok(sm) => sm,
                Err(e) => {
                    worker_send(
                        conn,
                        inj,
                        Frame::Error {
                            req_id: job.req_id,
                            message: format!("bad resume checkpoint: {e:#}"),
                        },
                    )?;
                    return Ok(());
                }
            }
        }
        None => StreamMatcher::for_chunk(cm),
    };
    let step = usize::try_from(job.checkpoint_every.max(1))
        .unwrap_or(usize::MAX)
        .max(1);
    sm.set_fold_bytes(step);
    let mut fed = 0usize;
    while fed < job.data.len() {
        let end = (fed + step).min(job.data.len());
        sm.feed(&job.data[fed..end]);
        *bytes_matched += (end - fed) as u64;
        fed = end;
        if fed < job.data.len() {
            worker_send(
                conn,
                inj,
                Frame::Checkpoint {
                    req_id: job.req_id,
                    ckpt: sm.checkpoint().to_bytes(),
                },
            )?;
        }
        if inj.should_kill(*bytes_matched) {
            // crash mid-chunk, after the last progress checkpoint: the
            // frontend resumes a survivor from it
            std::process::exit(4);
        }
    }
    sm.flush();
    worker_send(
        conn,
        inj,
        Frame::Result { req_id: job.req_id, ckpt: sm.checkpoint().to_bytes() },
    )?;
    Ok(())
}

/// Write one frame through the fault injector: honor delay, skip
/// dropped frames, and crash halfway through truncated ones.
fn worker_send(
    conn: &mut Conn,
    inj: &mut Injector,
    frame: Frame,
) -> std::io::Result<()> {
    let (action, delay_ms) = inj.action(frame.kind());
    if let Some(ms) = delay_ms {
        std::thread::sleep(Duration::from_millis(ms));
    }
    match action {
        Action::Send => proto::write_frame(conn, &frame),
        Action::Drop => Ok(()),
        Action::Truncate => {
            let bytes = frame.encode();
            let _ = conn.write(&bytes[..bytes.len() / 2]);
            let _ = conn.flush();
            // crash mid-send: the peer sees a torn frame then EOF
            std::process::exit(3);
        }
    }
}

// ---------------------------------------------------------------------
// frontend
// ---------------------------------------------------------------------

/// Frontend configuration for [`ProcCluster::start`].
#[derive(Clone, Debug)]
pub struct ProcConfig {
    /// worker processes to spawn
    pub workers: usize,
    /// socket family ([`Transport::default_for_host`] by default)
    pub transport: Transport,
    /// worker binary to spawn; `None` uses `std::env::current_exe()`
    /// (integration tests pass `env!("CARGO_BIN_EXE_specdfa")`, since
    /// their own executable is the test harness, not `specdfa`)
    pub worker_bin: Option<PathBuf>,
    /// spawn → `Hello` attach deadline
    pub connect_timeout: Duration,
    /// per-attempt deadline for one chunk request
    pub request_timeout: Duration,
    /// deadline for a heartbeat echo
    pub heartbeat_timeout: Duration,
    /// total chunk retries allowed per serve before degrading
    pub retry_budget: u32,
    /// first retry backoff (doubles per retry, capped)
    pub backoff_base: Duration,
    /// backoff ceiling
    pub backoff_cap: Duration,
    /// bytes between streamed worker checkpoints (the failover grain)
    pub checkpoint_every: usize,
    /// inputs shorter than `workers × this` use fewer workers; inputs
    /// shorter than this skip the cluster and run locally
    pub min_chunk_bytes: usize,
    /// §4.1 profiling runs each worker performs at attach
    pub profile_runs: usize,
    /// symbols per worker profiling run
    pub profile_sample_syms: usize,
    /// cluster-level fault-injection spec
    /// ([`super::fault::parse_cluster_spec`] grammar), threaded to the
    /// targeted workers' command lines
    pub fault_spec: Option<String>,
    /// execution policy for the local (degraded-mode) matcher
    pub policy: ExecPolicy,
}

impl Default for ProcConfig {
    fn default() -> ProcConfig {
        ProcConfig {
            workers: 2,
            transport: Transport::default_for_host(),
            worker_bin: None,
            connect_timeout: Duration::from_secs(20),
            request_timeout: Duration::from_secs(10),
            heartbeat_timeout: Duration::from_secs(2),
            retry_budget: 4,
            backoff_base: Duration::from_millis(25),
            backoff_cap: Duration::from_secs(1),
            checkpoint_every: 64 << 10,
            min_chunk_bytes: 4 << 10,
            profile_runs: 3,
            profile_sample_syms: 1 << 17,
            fault_spec: None,
            policy: ExecPolicy::default(),
        }
    }
}

/// Cluster-wide telemetry counters (monotonic since
/// [`ProcCluster::start`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterStats {
    /// `match_bytes` calls
    pub serves: u64,
    /// serves answered by the worker fleet
    pub cluster_serves: u64,
    /// serves answered in-process because the cluster was unusable
    /// (rung 4 of the degradation ladder)
    pub degraded: u64,
    /// serves answered locally because the input was below the
    /// cluster-efficiency floor (`min_chunk_bytes`) — not a failure
    pub local_small: u64,
    /// chunk retry attempts (each backoff-delayed reassignment)
    pub retries: u64,
    /// chunks reassigned from a dead worker to a survivor
    pub failovers: u64,
    /// workers declared dead (crash, timeout, bad frame, stalled
    /// heartbeat)
    pub worker_deaths: u64,
    /// failovers that resumed from a streamed checkpoint
    pub resumed_serves: u64,
    /// bytes of matching work **not** redone thanks to checkpoint
    /// resume (the victim's progress the survivor inherited)
    pub resumed_bytes: u64,
    /// heartbeat probes sent
    pub heartbeats: u64,
    /// heartbeat probes that timed out or came back wrong
    pub heartbeat_failures: u64,
    /// input bytes submitted
    pub bytes: u64,
    /// per-worker attach-time capacity rates (symbols/µs; 0.0 = never
    /// attached)
    pub worker_rates: Vec<f64>,
    /// workers currently alive
    pub live_workers: usize,
}

/// Per-serve record carried as [`Detail::Cluster`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ProcOutcome {
    /// chunks the input was partitioned into
    pub chunks: usize,
    /// retry attempts this serve needed
    pub retries: u64,
    /// chunks that failed over to a survivor
    pub failovers: u64,
    /// bytes inherited from streamed checkpoints instead of rescanned
    pub resumed_bytes: u64,
}

#[derive(Default)]
struct Counters {
    serves: AtomicU64,
    cluster_serves: AtomicU64,
    degraded: AtomicU64,
    local_small: AtomicU64,
    retries: AtomicU64,
    failovers: AtomicU64,
    worker_deaths: AtomicU64,
    resumed_serves: AtomicU64,
    resumed_bytes: AtomicU64,
    heartbeats: AtomicU64,
    heartbeat_failures: AtomicU64,
    bytes: AtomicU64,
}

struct WorkerSlot {
    alive: bool,
    conn: Option<Conn>,
    child: Option<Child>,
    rate: f64,
    patterns: HashMap<Pattern, u32>,
    next_pattern_id: u32,
}

impl WorkerSlot {
    fn dead() -> WorkerSlot {
        WorkerSlot {
            alive: false,
            conn: None,
            child: None,
            rate: 0.0,
            patterns: HashMap::new(),
            next_pattern_id: 0,
        }
    }

    /// Declare the worker dead: close the socket, reap the process.
    fn bury(&mut self) {
        self.alive = false;
        self.conn = None;
        if let Some(mut child) = self.child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// A running multi-process cluster: spawned workers, their sockets,
/// and the retry/failover state machine.  See the [module docs](self).
pub struct ProcCluster {
    config: ProcConfig,
    slots: Vec<Mutex<WorkerSlot>>,
    counters: Counters,
    next_req: AtomicU64,
    local: Mutex<HashMap<Pattern, std::sync::Arc<CompiledMatcher>>>,
}

impl fmt::Debug for ProcCluster {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ProcCluster")
            .field("workers", &self.slots.len())
            .field("live", &self.live_workers())
            .finish()
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl ProcCluster {
    /// Spawn `config.workers` worker processes, wait for each to
    /// attach with its measured capacity, and return the frontend.
    /// Workers that fail to spawn or attach start out dead; a cluster
    /// with zero live workers is still usable — every serve degrades
    /// to the in-process matcher.
    pub fn start(config: ProcConfig) -> Result<ProcCluster> {
        let fault_plans: HashMap<usize, FaultPlan> = match &config.fault_spec
        {
            Some(spec) => parse_cluster_spec(spec)?.into_iter().collect(),
            None => HashMap::new(),
        };
        let (listener, addr) = Listener::bind(config.transport)?;
        let bin = match &config.worker_bin {
            Some(bin) => bin.clone(),
            None => std::env::current_exe()
                .context("resolve worker binary path")?,
        };
        let mut slots: Vec<WorkerSlot> =
            (0..config.workers).map(|_| WorkerSlot::dead()).collect();
        let mut spawned = 0usize;
        for (k, slot) in slots.iter_mut().enumerate() {
            let mut cmd = Command::new(&bin);
            cmd.arg("worker")
                .arg("--connect")
                .arg(&addr)
                .arg("--id")
                .arg(k.to_string())
                .arg("--profile-runs")
                .arg(config.profile_runs.to_string())
                .arg("--profile-syms")
                .arg(config.profile_sample_syms.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null());
            if let Some(plan) = fault_plans.get(&k) {
                if !plan.is_benign() {
                    cmd.arg("--fault").arg(plan.to_spec());
                }
            }
            match cmd.spawn() {
                Ok(child) => {
                    slot.child = Some(child);
                    spawned += 1;
                }
                Err(_) => slot.bury(),
            }
        }
        // collect Hello frames; workers identify themselves, so accept
        // order doesn't matter
        let deadline = Instant::now() + config.connect_timeout;
        let mut attached = 0usize;
        while attached < spawned {
            let Ok(mut conn) = listener.accept_by(deadline) else {
                break;
            };
            let _ = conn.set_read_timeout(Some(config.connect_timeout));
            match proto::read_frame(&mut conn) {
                Ok(Frame::Hello { worker, rate_syms_per_us }) => {
                    let idx = worker as usize;
                    if idx < slots.len() && slots[idx].conn.is_none() {
                        slots[idx].conn = Some(conn);
                        slots[idx].alive = true;
                        slots[idx].rate = if rate_syms_per_us > 0.0 {
                            rate_syms_per_us
                        } else {
                            1.0
                        };
                        attached += 1;
                    }
                }
                _ => attached += 1, // garbled attach: drop the conn
            }
        }
        let cluster = ProcCluster {
            config,
            slots: slots.into_iter().map(Mutex::new).collect(),
            counters: Counters::default(),
            next_req: AtomicU64::new(1),
            local: Mutex::new(HashMap::new()),
        };
        // reap any spawned-but-never-attached workers
        for slot in &cluster.slots {
            let mut slot = lock(slot);
            if !slot.alive {
                slot.bury();
            }
        }
        Ok(cluster)
    }

    /// Workers currently alive.
    pub fn live_workers(&self) -> usize {
        self.slots.iter().filter(|s| lock(s).alive).count()
    }

    /// Snapshot the telemetry counters.
    pub fn stats(&self) -> ClusterStats {
        let c = &self.counters;
        let mut rates = Vec::with_capacity(self.slots.len());
        let mut live = 0usize;
        for slot in &self.slots {
            let slot = lock(slot);
            rates.push(slot.rate);
            live += usize::from(slot.alive);
        }
        ClusterStats {
            serves: c.serves.load(Ordering::Relaxed),
            cluster_serves: c.cluster_serves.load(Ordering::Relaxed),
            degraded: c.degraded.load(Ordering::Relaxed),
            local_small: c.local_small.load(Ordering::Relaxed),
            retries: c.retries.load(Ordering::Relaxed),
            failovers: c.failovers.load(Ordering::Relaxed),
            worker_deaths: c.worker_deaths.load(Ordering::Relaxed),
            resumed_serves: c.resumed_serves.load(Ordering::Relaxed),
            resumed_bytes: c.resumed_bytes.load(Ordering::Relaxed),
            heartbeats: c.heartbeats.load(Ordering::Relaxed),
            heartbeat_failures: c.heartbeat_failures.load(Ordering::Relaxed),
            bytes: c.bytes.load(Ordering::Relaxed),
            worker_rates: rates,
            live_workers: live,
        }
    }

    /// Probe every live worker with a nonce echo; workers that fail to
    /// echo in time are declared dead.  Returns the live count.
    pub fn heartbeat(&self) -> usize {
        let mut live = 0usize;
        for slot in &self.slots {
            let mut slot = lock(slot);
            if !slot.alive {
                continue;
            }
            self.counters.heartbeats.fetch_add(1, Ordering::Relaxed);
            let nonce = self.next_req.fetch_add(1, Ordering::Relaxed);
            // alive implies a connection; treat the impossible gap as a
            // failed probe instead of panicking the frontend
            let ok = match slot.conn.as_mut() {
                Some(conn) => Self::heartbeat_conn(
                    conn,
                    nonce,
                    self.config.heartbeat_timeout,
                ),
                None => false,
            };
            if ok {
                live += 1;
            } else {
                self.counters
                    .heartbeat_failures
                    .fetch_add(1, Ordering::Relaxed);
                self.counters.worker_deaths.fetch_add(1, Ordering::Relaxed);
                slot.bury();
            }
        }
        live
    }

    fn heartbeat_conn(conn: &mut Conn, nonce: u64, timeout: Duration) -> bool {
        if conn.set_read_timeout(Some(timeout.max(MIN_TIMEOUT))).is_err() {
            return false;
        }
        if proto::write_frame(conn, &Frame::Heartbeat { nonce }).is_err() {
            return false;
        }
        matches!(
            proto::read_frame(conn),
            Ok(Frame::Heartbeat { nonce: echo }) if echo == nonce
        )
    }

    /// Serve one membership test through the cluster.  Never fails on
    /// worker trouble: every rung of the degradation ladder ends in a
    /// verdict equal to `Engine::Sequential`'s (an `Err` means the
    /// *pattern itself* doesn't compile).
    pub fn match_bytes(
        &self,
        pattern: &Pattern,
        input: &[u8],
    ) -> Result<Outcome> {
        let t0 = Instant::now();
        self.counters.serves.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes.fetch_add(input.len() as u64, Ordering::Relaxed);
        let local = self.local_matcher(pattern)?;
        if input.len() < self.config.min_chunk_bytes.max(1) {
            self.counters.local_small.fetch_add(1, Ordering::Relaxed);
            return local.run_bytes(input);
        }
        // heartbeat sweep: stalled or crashed workers leave the
        // partition before any chunk is cut for them
        if self.heartbeat() == 0 {
            return self.degrade(&local, input);
        }
        let live: Vec<usize> = (0..self.slots.len())
            .filter(|&k| lock(&self.slots[k]).alive)
            .collect();
        if live.is_empty() {
            return self.degrade(&local, input);
        }
        // Eq. (1): capacity-weighted partition over the live workers,
        // capped so no chunk falls below the efficiency floor
        let usable = live
            .len()
            .min((input.len() / self.config.min_chunk_bytes.max(1)).max(1));
        let live = &live[..usable];
        let rates: Vec<f64> =
            live.iter().map(|&k| lock(&self.slots[k]).rate.max(1e-9)).collect();
        let weights = weights_from_capacities(&rates);
        let chunks: Vec<_> = partition(input.len(), &weights, 1)
            .into_iter()
            .filter(|c| !c.is_empty())
            .collect();
        // fan out: one thread per chunk drives one worker's socket
        let attempts: Vec<ChunkAttempt> = std::thread::scope(|scope| {
            let handles: Vec<_> = chunks
                .iter()
                .map(|chunk| {
                    let slot_idx = live[chunk.proc];
                    let data = &input[chunk.start..chunk.end];
                    scope.spawn(move || {
                        self.run_chunk(slot_idx, pattern, data, None)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        ChunkAttempt::failed(
                            "chunk thread panicked".into(),
                            None,
                        )
                    })
                })
                .collect()
        });
        // failover: retry failed chunks on survivors, resuming from
        // the victim's last streamed checkpoint
        let mut serve = ProcOutcome { chunks: chunks.len(), ..Default::default() };
        let mut lvs: Vec<Option<LVector>> = Vec::with_capacity(chunks.len());
        for (chunk, attempt) in chunks.iter().zip(attempts) {
            match self.recover_chunk(pattern, input, chunk, attempt, &mut serve)
            {
                Some(lv) => lvs.push(Some(lv)),
                None => return self.degrade(&local, input),
            }
        }
        // Fig. 9 / Eq. 9: compose the per-chunk maps in input order
        let mut composed: Option<LVector> = None;
        for lv in lvs.into_iter().flatten() {
            composed = Some(match composed {
                Some(acc) => acc.compose(&lv),
                None => lv,
            });
        }
        let dfa = local.dfa();
        let fin = match composed {
            Some(lv) => lv.get(dfa.start),
            None => dfa.start, // every chunk empty: n == 0
        };
        self.counters.cluster_serves.fetch_add(1, Ordering::Relaxed);
        self.counters.retries.fetch_add(serve.retries, Ordering::Relaxed);
        self.counters.failovers.fetch_add(serve.failovers, Ordering::Relaxed);
        self.counters
            .resumed_bytes
            .fetch_add(serve.resumed_bytes, Ordering::Relaxed);
        if serve.resumed_bytes > 0 {
            self.counters.resumed_serves.fetch_add(1, Ordering::Relaxed);
        }
        let per_worker: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        Ok(Outcome {
            engine: EngineKind::Cluster,
            n: input.len(),
            accepted: dfa.accepting[fin as usize],
            final_state: Some(fin),
            makespan: per_worker.iter().copied().max().unwrap_or(0),
            overhead_syms: 0,
            per_worker_syms: per_worker,
            wall_s: t0.elapsed().as_secs_f64(),
            selection: None,
            detail: Detail::Cluster(serve),
        })
    }

    /// Drive the retry/backoff loop for one failed chunk.  Returns the
    /// chunk's L-vector, or `None` when the budget or the fleet ran
    /// out (the caller degrades the whole serve).
    fn recover_chunk(
        &self,
        pattern: &Pattern,
        input: &[u8],
        chunk: &crate::speculative::partition::Chunk,
        attempt: ChunkAttempt,
        serve: &mut ProcOutcome,
    ) -> Option<LVector> {
        if let Some(lv) = attempt.lv {
            return Some(lv);
        }
        let mut last_ckpt = attempt.last_ckpt;
        let mut backoff = self.config.backoff_base;
        let mut reassigned = false;
        loop {
            if serve.retries >= u64::from(self.config.retry_budget) {
                return None;
            }
            let target = self.pick_live(chunk.proc)?;
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(self.config.backoff_cap);
            serve.retries += 1;
            if !reassigned {
                reassigned = true;
                serve.failovers += 1;
            }
            let resume = last_ckpt.clone();
            let resume_off = resume
                .as_ref()
                .map(|c| c.offset() as usize)
                .unwrap_or(0)
                .min(chunk.len());
            let data = &input[chunk.start + resume_off..chunk.end];
            let next =
                self.run_chunk(target, pattern, data, resume.clone());
            if let Some(lv) = next.lv {
                serve.resumed_bytes += resume_off as u64;
                return Some(lv);
            }
            // carry forward whichever checkpoint got further
            let next_off =
                next.last_ckpt.as_ref().map(|c| c.offset()).unwrap_or(0);
            let prev_off =
                last_ckpt.as_ref().map(|c| c.offset()).unwrap_or(0);
            if next_off > prev_off {
                last_ckpt = next.last_ckpt;
            }
        }
    }

    /// First live worker, scanning round-robin from `after + 1`.
    fn pick_live(&self, after: usize) -> Option<usize> {
        let n = self.slots.len();
        (1..=n)
            .map(|d| (after + d) % n)
            .find(|&k| lock(&self.slots[k]).alive)
    }

    /// One attempt at matching `data` (a chunk suffix when resuming)
    /// on worker `slot_idx`.  Any protocol trouble buries the worker.
    fn run_chunk(
        &self,
        slot_idx: usize,
        pattern: &Pattern,
        data: &[u8],
        resume: Option<Checkpoint>,
    ) -> ChunkAttempt {
        let mut slot = lock(&self.slots[slot_idx]);
        if !slot.alive {
            return ChunkAttempt::failed("worker already dead".into(), resume);
        }
        let expected = resume.as_ref().map(|c| c.offset()).unwrap_or(0)
            + data.len() as u64;
        let attempt =
            self.drive_request(&mut slot, pattern, data, &resume, expected);
        if attempt.lv.is_some() {
            return attempt;
        }
        self.counters.worker_deaths.fetch_add(1, Ordering::Relaxed);
        slot.bury();
        // resume from whichever checkpoint is furthest along
        let best = match (attempt.last_ckpt, resume) {
            (Some(p), Some(r)) => {
                Some(if p.offset() >= r.offset() { p } else { r })
            }
            (Some(p), None) => Some(p),
            (None, r) => r,
        };
        ChunkAttempt { lv: None, last_ckpt: best, error: attempt.error }
    }

    fn drive_request(
        &self,
        slot: &mut WorkerSlot,
        pattern: &Pattern,
        data: &[u8],
        resume: &Option<Checkpoint>,
        expected_offset: u64,
    ) -> ChunkAttempt {
        let deadline = Instant::now() + self.config.request_timeout;
        let pattern_id = match self.compile_on(slot, pattern, deadline) {
            Ok(id) => id,
            Err(e) => return ChunkAttempt::failed(format!("{e:#}"), None),
        };
        let req_id = self.next_req.fetch_add(1, Ordering::Relaxed);
        let frame = Frame::Match {
            req_id,
            pattern_id,
            checkpoint_every: self.config.checkpoint_every.max(1) as u64,
            resume: resume.as_ref().map(|c| c.to_bytes()),
            data: data.to_vec(),
        };
        let Some(conn) = slot.conn.as_mut() else {
            return ChunkAttempt::failed(
                "worker has no connection".into(),
                None,
            );
        };
        if let Err(e) = proto::write_frame(conn, &frame) {
            return ChunkAttempt::failed(format!("send match: {e}"), None);
        }
        let mut progress: Option<Checkpoint> = None;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return ChunkAttempt::failed(
                    "request deadline exceeded".into(),
                    progress,
                );
            }
            if conn
                .set_read_timeout(Some((deadline - now).max(MIN_TIMEOUT)))
                .is_err()
            {
                return ChunkAttempt::failed("socket lost".into(), progress);
            }
            match proto::read_frame(conn) {
                Ok(Frame::Checkpoint { req_id: r, ckpt }) if r == req_id => {
                    match Checkpoint::from_bytes(&ckpt) {
                        Ok(c) => progress = Some(c),
                        Err(e) => {
                            return ChunkAttempt::failed(
                                format!("bad progress checkpoint: {e:#}"),
                                progress,
                            )
                        }
                    }
                }
                Ok(Frame::Result { req_id: r, ckpt }) if r == req_id => {
                    return match Checkpoint::from_bytes(&ckpt) {
                        Ok(c) if c.offset() == expected_offset
                            && c.buffered() == 0 =>
                        {
                            ChunkAttempt {
                                lv: Some(c.lvector().clone()),
                                last_ckpt: None,
                                error: None,
                            }
                        }
                        Ok(c) => ChunkAttempt::failed(
                            format!(
                                "result covers {} of {expected_offset} bytes",
                                c.offset()
                            ),
                            progress,
                        ),
                        Err(e) => ChunkAttempt::failed(
                            format!("bad result checkpoint: {e:#}"),
                            progress,
                        ),
                    };
                }
                Ok(Frame::Error { message, .. }) => {
                    return ChunkAttempt::failed(
                        format!("worker error: {message}"),
                        progress,
                    )
                }
                Ok(other) => {
                    return ChunkAttempt::failed(
                        format!(
                            "unexpected {} frame mid-request",
                            other.kind().name()
                        ),
                        progress,
                    )
                }
                Err(e) => {
                    return ChunkAttempt::failed(
                        format!("transport: {e:#}"),
                        progress,
                    )
                }
            }
        }
    }

    /// Ensure `pattern` is compiled on this worker; returns its id.
    fn compile_on(
        &self,
        slot: &mut WorkerSlot,
        pattern: &Pattern,
        deadline: Instant,
    ) -> Result<u32> {
        if let Some(&id) = slot.patterns.get(pattern) {
            return Ok(id);
        }
        let id = slot.next_pattern_id;
        let Some(conn) = slot.conn.as_mut() else {
            bail!("worker has no connection");
        };
        let remaining =
            deadline.saturating_duration_since(Instant::now()).max(MIN_TIMEOUT);
        conn.set_read_timeout(Some(remaining))?;
        proto::write_frame(
            conn,
            &Frame::Compile { pattern_id: id, pattern: pattern.clone() },
        )?;
        match proto::read_frame(conn)? {
            Frame::CompileOk { pattern_id, .. } if pattern_id == id => {
                slot.next_pattern_id += 1;
                slot.patterns.insert(pattern.clone(), id);
                Ok(id)
            }
            Frame::Error { message, .. } => {
                bail!("worker refused pattern: {message}")
            }
            other => bail!(
                "unexpected {} frame while compiling",
                other.kind().name()
            ),
        }
    }

    /// Rung 4: the cluster is unusable — answer in-process.  Still the
    /// sequential verdict, never an error.
    fn degrade(
        &self,
        local: &CompiledMatcher,
        input: &[u8],
    ) -> Result<Outcome> {
        self.counters.degraded.fetch_add(1, Ordering::Relaxed);
        local.run_bytes(input)
    }

    fn local_matcher(
        &self,
        pattern: &Pattern,
    ) -> Result<std::sync::Arc<CompiledMatcher>> {
        let mut cache = lock(&self.local);
        if let Some(cm) = cache.get(pattern) {
            return Ok(cm.clone());
        }
        let cm = std::sync::Arc::new(CompiledMatcher::compile(
            pattern,
            Engine::Auto,
            self.config.policy.clone(),
        )?);
        cache.insert(pattern.clone(), cm.clone());
        Ok(cm)
    }

    /// Shut the fleet down (graceful `Shutdown` frames, then reap) and
    /// return the final stats.
    pub fn shutdown(self) -> ClusterStats {
        let stats = self.stats();
        self.teardown();
        stats
    }

    fn teardown(&self) {
        for slot in &self.slots {
            let mut slot = lock(slot);
            if slot.alive {
                if let Some(conn) = slot.conn.as_mut() {
                    let _ = proto::write_frame(conn, &Frame::Shutdown);
                }
            }
            slot.bury();
        }
    }
}

impl Drop for ProcCluster {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Floor for socket timeouts: zero is invalid, and sub-millisecond
/// deadlines just busy-spin.
const MIN_TIMEOUT: Duration = Duration::from_millis(1);

struct ChunkAttempt {
    lv: Option<LVector>,
    last_ckpt: Option<Checkpoint>,
    #[allow(dead_code)] // kept for debugging/telemetry symmetry
    error: Option<String>,
}

impl ChunkAttempt {
    fn failed(message: String, last_ckpt: Option<Checkpoint>) -> ChunkAttempt {
        ChunkAttempt { lv: None, last_ckpt, error: Some(message) }
    }
}
