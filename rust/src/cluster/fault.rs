//! Deterministic fault injection for the process cluster.
//!
//! Robustness claims are only as good as the failures they were tested
//! against, so every failure mode the frontend recovers from is
//! *injectable on purpose*: a [`FaultPlan`] rides into each worker on
//! its command line (`specdfa worker --fault SPEC`) and the worker's
//! transport consults it before every outbound frame and every byte of
//! matching work.  Plans are pure data — parsing a spec, printing it
//! back and parsing it again yields the same plan — so a CI failure
//! reproduces from the spec string alone.
//!
//! Spec grammar (comma-separated directives, one plan per worker):
//!
//! ```text
//!   kill@BYTES          exit mid-chunk after matching BYTES bytes
//!   drop=KIND[:N]       silently skip the Nth outbound KIND frame
//!   trunc=KIND[:N]      write half of the Nth KIND frame, then exit
//!   delay=MS            sleep MS ms before every outbound frame
//!   stall               stop answering heartbeats (but keep serving)
//! ```
//!
//! `KIND` is a [`FrameKind`] name (`result`, `checkpoint`, …) or `any`;
//! `N` is 1-based and defaults to 1.  A cluster-level spec targets
//! workers by index: `w1:kill@65536;w0:stall`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::proto::FrameKind;

/// Which outbound frames a [`FaultPlan`] directive selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameSel {
    /// every frame kind counts toward the occurrence number
    Any,
    /// only frames of this kind count
    Kind(FrameKind),
}

impl FrameSel {
    fn name(self) -> String {
        match self {
            FrameSel::Any => "any".to_string(),
            FrameSel::Kind(k) => k.name().to_string(),
        }
    }

    fn parse(name: &str) -> Result<FrameSel> {
        if name == "any" {
            return Ok(FrameSel::Any);
        }
        Ok(FrameSel::Kind(FrameKind::parse(name)?))
    }
}

/// What the transport should do with the outbound frame it is about to
/// write (decided by [`Injector::action`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// write the frame normally
    Send,
    /// skip the frame entirely (the stream stays aligned; the peer
    /// simply never sees it and times out waiting)
    Drop,
    /// write only the first half of the encoding, then crash — the
    /// peer's decoder sees a truncated frame
    Truncate,
}

/// A deterministic per-worker failure script.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// exit the process after this many bytes of chunk matching
    pub kill_after_bytes: Option<u64>,
    /// drop the Nth outbound frame matching the selector (1-based)
    pub drop: Option<(FrameSel, u32)>,
    /// truncate the Nth outbound frame matching the selector (1-based)
    pub truncate: Option<(FrameSel, u32)>,
    /// sleep this long before every outbound frame, in milliseconds
    pub delay_ms: Option<u64>,
    /// swallow heartbeat probes instead of echoing them
    pub stall_heartbeats: bool,
}

impl FaultPlan {
    /// True when the plan injects nothing.
    pub fn is_benign(&self) -> bool {
        *self == FaultPlan::default()
    }

    /// Parse a comma-separated directive list (see module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for directive in spec.split(',') {
            let directive = directive.trim();
            if directive.is_empty() {
                continue;
            }
            if directive == "stall" {
                plan.stall_heartbeats = true;
            } else if let Some(bytes) = directive.strip_prefix("kill@") {
                plan.kill_after_bytes = Some(
                    bytes.parse().context("kill@BYTES wants an integer")?,
                );
            } else if let Some(ms) = directive.strip_prefix("delay=") {
                plan.delay_ms =
                    Some(ms.parse().context("delay=MS wants an integer")?);
            } else if let Some(sel) = directive.strip_prefix("drop=") {
                plan.drop = Some(parse_sel(sel)?);
            } else if let Some(sel) = directive.strip_prefix("trunc=") {
                plan.truncate = Some(parse_sel(sel)?);
            } else {
                bail!("unknown fault directive {directive:?}");
            }
        }
        Ok(plan)
    }

    /// Print the plan back as a spec string ([`FaultPlan::parse`]
    /// roundtrips it).
    pub fn to_spec(&self) -> String {
        let mut parts = Vec::new();
        if let Some(b) = self.kill_after_bytes {
            parts.push(format!("kill@{b}"));
        }
        if let Some((sel, n)) = self.drop {
            parts.push(format!("drop={}:{n}", sel.name()));
        }
        if let Some((sel, n)) = self.truncate {
            parts.push(format!("trunc={}:{n}", sel.name()));
        }
        if let Some(ms) = self.delay_ms {
            parts.push(format!("delay={ms}"));
        }
        if self.stall_heartbeats {
            parts.push("stall".to_string());
        }
        parts.join(",")
    }
}

fn parse_sel(text: &str) -> Result<(FrameSel, u32)> {
    let (name, n) = match text.split_once(':') {
        Some((name, n)) => {
            (name, n.parse::<u32>().context("frame ordinal wants an integer")?)
        }
        None => (text, 1),
    };
    if n == 0 {
        bail!("frame ordinals are 1-based");
    }
    Ok((FrameSel::parse(name)?, n))
}

/// Parse a cluster-level spec: `;`-separated `wK:PLAN` entries, each
/// targeting worker index `K`.  A bare plan with no `wK:` prefix
/// targets worker 0.
pub fn parse_cluster_spec(spec: &str) -> Result<Vec<(usize, FaultPlan)>> {
    let mut out = Vec::new();
    for entry in spec.split(';') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (worker, plan_spec) = match entry.split_once(':') {
            Some((w, rest)) if w.starts_with('w') => {
                let idx: usize = w[1..]
                    .parse()
                    .with_context(|| format!("bad worker selector {w:?}"))?;
                (idx, rest)
            }
            _ => (0, entry),
        };
        out.push((worker, FaultPlan::parse(plan_spec)?));
    }
    Ok(out)
}

/// The worker-side injection state machine: counts outbound frames per
/// kind and tells the transport what to do with each one.
#[derive(Debug)]
pub struct Injector {
    plan: FaultPlan,
    sent_any: u32,
    sent_by_kind: HashMap<FrameKind, u32>,
}

impl Injector {
    /// Fresh injector for a plan.
    pub fn new(plan: FaultPlan) -> Injector {
        Injector { plan, sent_any: 0, sent_by_kind: HashMap::new() }
    }

    /// The plan being executed.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Decide the fate of the next outbound frame of `kind`, advancing
    /// the occurrence counters.  Also returns the pre-send delay.
    pub fn action(&mut self, kind: FrameKind) -> (Action, Option<u64>) {
        self.sent_any += 1;
        let by_kind = self.sent_by_kind.entry(kind).or_insert(0);
        *by_kind += 1;
        let matches = |directive: &Option<(FrameSel, u32)>| match directive {
            Some((FrameSel::Any, n)) => *n == self.sent_any,
            Some((FrameSel::Kind(k), n)) => *k == kind && *n == *by_kind,
            None => false,
        };
        let action = if matches(&self.plan.truncate) {
            Action::Truncate
        } else if matches(&self.plan.drop) {
            Action::Drop
        } else {
            Action::Send
        };
        (action, self.plan.delay_ms)
    }

    /// True once `bytes_matched` crosses the plan's kill threshold.
    pub fn should_kill(&self, bytes_matched: u64) -> bool {
        matches!(self.plan.kill_after_bytes, Some(b) if bytes_matched >= b)
    }

    /// True when heartbeat probes must be swallowed.
    pub fn stall_heartbeats(&self) -> bool {
        self.plan.stall_heartbeats
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // unwrap in tests is a test failure
mod tests {
    use super::*;

    #[test]
    fn specs_roundtrip_through_parse_and_print() {
        for spec in [
            "kill@65536",
            "drop=result:1",
            "trunc=checkpoint:2",
            "delay=5",
            "stall",
            "kill@1024,drop=result:1,trunc=any:3,delay=2,stall",
        ] {
            let plan = FaultPlan::parse(spec).unwrap();
            let printed = plan.to_spec();
            assert_eq!(FaultPlan::parse(&printed).unwrap(), plan, "{spec}");
        }
        // defaulted ordinal prints explicitly but parses back equal
        let plan = FaultPlan::parse("drop=result").unwrap();
        assert_eq!(plan.drop, Some((FrameSel::Kind(FrameKind::Result), 1)));
        assert!(FaultPlan::parse("").unwrap().is_benign());
        assert!(FaultPlan::parse("explode").is_err());
        assert!(FaultPlan::parse("kill@lots").is_err());
        assert!(FaultPlan::parse("drop=result:0").is_err());
        assert!(FaultPlan::parse("drop=warp").is_err());
    }

    #[test]
    fn cluster_specs_target_workers() {
        let plans =
            parse_cluster_spec("w1:kill@4096;w0:stall;w2:drop=result")
                .unwrap();
        assert_eq!(plans.len(), 3);
        assert_eq!(plans[0].0, 1);
        assert_eq!(plans[0].1.kill_after_bytes, Some(4096));
        assert_eq!(plans[1].0, 0);
        assert!(plans[1].1.stall_heartbeats);
        assert_eq!(plans[2].0, 2);
        // bare plan targets worker 0
        let bare = parse_cluster_spec("kill@10").unwrap();
        assert_eq!(bare, vec![(0, FaultPlan::parse("kill@10").unwrap())]);
        assert!(parse_cluster_spec("wx:stall").is_err());
    }

    #[test]
    fn injector_counts_occurrences_per_kind() {
        let plan = FaultPlan::parse("drop=checkpoint:2").unwrap();
        let mut inj = Injector::new(plan);
        assert_eq!(inj.action(FrameKind::Hello).0, Action::Send);
        assert_eq!(inj.action(FrameKind::Checkpoint).0, Action::Send);
        // an interleaved other-kind frame doesn't advance the counter
        assert_eq!(inj.action(FrameKind::Result).0, Action::Send);
        assert_eq!(inj.action(FrameKind::Checkpoint).0, Action::Drop);
        assert_eq!(inj.action(FrameKind::Checkpoint).0, Action::Send);
    }

    #[test]
    fn injector_any_selector_counts_all_frames() {
        let plan = FaultPlan::parse("trunc=any:3,delay=7").unwrap();
        let mut inj = Injector::new(plan);
        assert_eq!(inj.action(FrameKind::Hello), (Action::Send, Some(7)));
        assert_eq!(inj.action(FrameKind::CompileOk), (Action::Send, Some(7)));
        assert_eq!(
            inj.action(FrameKind::Checkpoint),
            (Action::Truncate, Some(7))
        );
    }

    #[test]
    fn kill_threshold_and_stall() {
        let inj = Injector::new(FaultPlan::parse("kill@100,stall").unwrap());
        assert!(!inj.should_kill(99));
        assert!(inj.should_kill(100));
        assert!(inj.stall_heartbeats());
        let benign = Injector::new(FaultPlan::default());
        assert!(!benign.should_kill(u64::MAX));
        assert!(!benign.stall_heartbeats());
    }
}
