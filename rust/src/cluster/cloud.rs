//! CloudMatcher: the paper's distributed-memory DFA matching on a
//! simulated EC2 cluster (§5.2, §6.2).
//!
//! The matching computation is executed for real (chunk L-vectors are
//! computed with the same flat-table loop as the multicore matcher, and
//! the final state is checked against sequential semantics by tests); the
//! *parallel timing* is simulated from:
//!
//!   worker compute time  = symbols matched / (base rate × core capacity)
//!   merge critical path  = per-strategy message/compose schedule with
//!                          latencies sampled from the paper's measured
//!                          EC2 distributions (network.rs)
//!
//! This reproduces the quantities of Fig. 14 (speedup + comm ratio),
//! Table 3 (load-balance stddev), and Fig. 19 (input-size scaling).

use crate::automata::{Dfa, FlatDfa};
use crate::speculative::lookahead::Lookahead;
use crate::speculative::lvector::LVector;
use crate::speculative::matcher::plan_chunks;
use crate::speculative::merge::MergeStrategy;
use crate::speculative::profile::weights_from_capacities;
use crate::util::rng::Rng;
use crate::util::stats;

use super::network::LatencyModel;
use super::node::ClusterSpec;

/// ns per (compose per-state lookup) in merge cost accounting.
const COMPOSE_NS_PER_STATE: f64 = 2.0;
/// ns per single-state map lookup (Eq. 8 step).
const LOOKUP_NS: f64 = 50.0;

/// Result of one simulated-cluster run: real matching outcome plus the
/// priced timing model.
#[derive(Clone, Debug)]
pub struct CloudOutcome {
    /// delta*(q0, input) — identical to the sequential run
    pub final_state: u32,
    /// membership verdict: final_state ∈ F
    pub accepted: bool,
    /// partitioning parameter (|Q| or I_max,r)
    pub m: usize,
    /// per-worker real matching work performed, in symbols
    pub per_worker_syms: Vec<usize>,
    /// per-worker simulated compute time, µs
    pub per_worker_us: Vec<f64>,
    /// end-to-end simulated time (compute + merge critical path), µs
    pub makespan_us: f64,
    /// communication + merge component (makespan − slowest compute), µs
    pub comm_us: f64,
    /// simulated sequential time on one fast core, µs
    pub seq_us: f64,
}

impl CloudOutcome {
    /// Simulated speedup over the one-fast-core sequential yardstick.
    pub fn speedup(&self) -> f64 {
        self.seq_us / self.makespan_us
    }

    /// Fig. 14(b,d): proportion of time spent communicating.
    pub fn comm_ratio(&self) -> f64 {
        self.comm_us / self.makespan_us
    }

    /// Table 3: proportional standard deviation of matching times.
    pub fn balance_cv(&self) -> f64 {
        stats::cv(&self.per_worker_us)
    }
}

/// Speculative DFA matching over a simulated cloud cluster.
///
/// Owns its DFA (cloned at construction) so a matcher outlives the
/// pattern-compilation scope — required by the [`crate::engine`] facade.
pub struct CloudMatcher {
    dfa: Dfa,
    flat: FlatDfa,
    cluster: ClusterSpec,
    latency: LatencyModel,
    r: usize,
    lookahead: Option<Lookahead>,
    merge: MergeStrategy,
    /// single-core matching rate of the capacity-1.0 instance, symbols/µs.
    /// Default calibrated from the paper-era hardware ballpark; the bench
    /// harness overrides it with the measured rate of this host.
    base_syms_per_us: f64,
    seed: u64,
    adaptive: bool,
}

impl CloudMatcher {
    /// A matcher over `dfa` on the given simulated cluster.
    pub fn new(dfa: &Dfa, cluster: ClusterSpec) -> Self {
        let cores = cluster.cores_per_node();
        CloudMatcher {
            dfa: dfa.clone(),
            flat: FlatDfa::from_dfa(dfa),
            cluster,
            latency: LatencyModel::default(),
            r: 0,
            lookahead: None,
            merge: MergeStrategy::Hierarchical { cores_per_node: cores },
            base_syms_per_us: 500.0,
            seed: 0x5EED,
            adaptive: false,
        }
    }

    /// Enable the adaptive fixed-point partition (see
    /// MatchPlan::adaptive_partition).
    pub fn adaptive_partition(mut self, on: bool) -> Self {
        self.adaptive = on;
        self
    }

    /// Enable the I_max,r optimization with `r` reverse lookahead symbols.
    pub fn lookahead(mut self, r: usize) -> Self {
        self.r = r;
        self.lookahead =
            if r > 0 { Some(Lookahead::analyze(&self.dfa, r)) } else { None };
        self
    }

    /// Inject a precomputed lookahead analysis (must come from this DFA);
    /// see [`crate::speculative::matcher::MatchPlan::with_lookahead`].
    pub fn with_lookahead(mut self, la: Lookahead) -> Self {
        self.r = la.r;
        self.lookahead = Some(la);
        self
    }

    /// Override the merge strategy (default: Fig. 9 hierarchical).
    pub fn merge_strategy(mut self, s: MergeStrategy) -> Self {
        self.merge = s;
        self
    }

    /// Replace the EC2 latency model.
    pub fn latency_model(mut self, m: LatencyModel) -> Self {
        self.latency = m;
        self
    }

    /// Set the capacity-1.0 single-core matching rate, symbols per µs.
    pub fn base_rate(mut self, syms_per_us: f64) -> Self {
        assert!(syms_per_us > 0.0);
        self.base_syms_per_us = syms_per_us;
        self
    }

    /// Seed for jitter/preemption/latency sampling (determinism).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// The partitioning parameter m: I_max,r with lookahead, |Q| without.
    pub fn i_max(&self) -> usize {
        self.lookahead
            .as_ref()
            .map(|la| la.i_max)
            .unwrap_or(self.dfa.num_states as usize)
    }

    /// The compiled DFA this matcher runs.
    pub fn dfa(&self) -> &Dfa {
        &self.dfa
    }

    /// Match raw bytes (applies the IBase class mapping first).
    pub fn run(&self, input: &[u8]) -> CloudOutcome {
        self.run_syms(&self.dfa.map_input(input))
    }

    /// Match pre-mapped dense symbols on the simulated cluster.
    pub fn run_syms(&self, syms: &[u32]) -> CloudOutcome {
        let mut rng = Rng::new(self.seed);
        let n = syms.len();
        let q = self.dfa.num_states as usize;
        let m = self.i_max().max(1);

        // ---- cluster invocation: actual per-worker capacities ----
        let workers = self.cluster.workers();
        let p = workers.len();
        let mut actual_caps: Vec<f64> = workers
            .iter()
            .map(|(_, cap)| {
                cap * (1.0 + self.cluster.capacity_jitter * rng.gauss())
                    .max(0.5)
            })
            .collect();

        // ---- offline profiling at cluster startup (§4.1) ----
        // profiling measures the jittered capacity (median of runs — the
        // paper notes preemption does NOT affect profiling)
        let profiled: Vec<f64> = actual_caps.clone();
        let weights = weights_from_capacities(&profiled);

        // hypervisor preemption strikes *after* profiling, during matching
        if !self.cluster.leave_one_core_idle {
            let mut idx = 0usize;
            for node in &self.cluster.nodes {
                let cores = node.cores;
                if rng.chance(self.cluster.preemption_prob) {
                    let victim = idx + rng.usize_below(cores);
                    actual_caps[victim] /= 10.0;
                }
                idx += cores;
            }
        }

        // ---- partition + real matching ----
        let (chunks, sets) = plan_chunks(
            &self.dfa,
            self.lookahead.as_ref(),
            syms,
            &weights,
            m,
            self.adaptive,
        );
        let mut lvectors: Vec<LVector> = Vec::with_capacity(p);
        let mut work_syms: Vec<usize> = Vec::with_capacity(p);
        for (chunk, set) in chunks.iter().zip(&sets) {
            let mut lv = LVector::identity(q);
            // shared 8-wide kernel, validated once per chunk; collapsing
            // stays off so the simulated timing below keeps pricing the
            // planned per-worker work
            let chunk_syms = self.flat.validate(&syms[chunk.start..chunk.end]);
            crate::speculative::chunk::match_chunk_states(
                &self.flat,
                &mut lv,
                set,
                chunk_syms,
                0,
            );
            work_syms.push(chunk.len() * set.len());
            lvectors.push(lv);
        }

        // ---- simulated timing ----
        let rate = |k: usize| self.base_syms_per_us * actual_caps[k];
        let per_worker_us: Vec<f64> = work_syms
            .iter()
            .enumerate()
            .map(|(k, &w)| w as f64 / rate(k))
            .collect();
        let compute_max = stats::max(&per_worker_us);

        let (final_state, finish_us) = self.merge_schedule(
            &lvectors,
            &per_worker_us,
            &workers,
            q,
            &mut rng,
        );

        // sequential yardstick: one fast (capacity = max nominal) core
        let best_cap = workers
            .iter()
            .map(|(_, c)| *c)
            .fold(f64::NEG_INFINITY, f64::max);
        let seq_us = n as f64 / (self.base_syms_per_us * best_cap);

        CloudOutcome {
            final_state,
            accepted: self.dfa.accepting[final_state as usize],
            m,
            per_worker_syms: work_syms,
            per_worker_us,
            makespan_us: finish_us,
            comm_us: (finish_us - compute_max).max(0.0),
            seq_us,
        }
    }

    /// Merge the chunk maps while computing the simulated critical path.
    /// Returns (final state, end-to-end finish time µs).
    fn merge_schedule(
        &self,
        lvecs: &[LVector],
        finish: &[f64],
        workers: &[(usize, f64)],
        q: usize,
        rng: &mut Rng,
    ) -> (u32, f64) {
        let compose_us = q as f64 * COMPOSE_NS_PER_STATE / 1000.0;
        let lookup_us = LOOKUP_NS / 1000.0;
        let node_of = |k: usize| workers[k.min(workers.len() - 1)].0;

        match self.merge {
            MergeStrategy::Sequential => {
                // all L-vectors travel to worker 0's node; the master
                // applies them in chunk order as they arrive
                let mut state = self.dfa.start;
                let mut t = finish[0];
                for (k, lv) in lvecs.iter().enumerate() {
                    if k > 0 {
                        let lat =
                            self.latency.sample_between(rng, node_of(k), node_of(0));
                        t = t.max(finish[k] + lat);
                    }
                    state = lv.get(state);
                    t += lookup_us;
                }
                (state, t)
            }
            MergeStrategy::BinaryTree => {
                // pairwise rounds; each combine waits for both operands
                // plus the message from the partner
                let mut maps: Vec<LVector> = lvecs.to_vec();
                let mut times: Vec<f64> = finish.to_vec();
                let mut homes: Vec<usize> =
                    (0..lvecs.len()).map(node_of).collect();
                while maps.len() > 1 {
                    let mut nm = Vec::new();
                    let mut nt = Vec::new();
                    let mut nh = Vec::new();
                    for i in (0..maps.len()).step_by(2) {
                        if i + 1 < maps.len() {
                            let lat = self.latency.sample_between(
                                rng, homes[i + 1], homes[i],
                            );
                            nm.push(maps[i].compose(&maps[i + 1]));
                            nt.push(
                                times[i].max(times[i + 1] + lat) + compose_us,
                            );
                            nh.push(homes[i]);
                        } else {
                            nm.push(maps[i].clone());
                            nt.push(times[i]);
                            nh.push(homes[i]);
                        }
                    }
                    maps = nm;
                    times = nt;
                    homes = nh;
                }
                (maps[0].get(self.dfa.start), times[0] + lookup_us)
            }
            MergeStrategy::Hierarchical { cores_per_node } => {
                // Fig. 9: tier 1 — node leaders compose their group
                let mut leader_ready: Vec<f64> = Vec::new();
                let mut leader_maps: Vec<LVector> = Vec::new();
                let mut leader_home: Vec<usize> = Vec::new();
                for (g, group) in lvecs.chunks(cores_per_node).enumerate() {
                    let base = g * cores_per_node;
                    let mut acc = group[0].clone();
                    let mut t = finish[base];
                    for (j, lv) in group.iter().enumerate().skip(1) {
                        let lat = self.latency.sample_intra(rng);
                        t = t.max(finish[base + j] + lat) + compose_us;
                        acc = acc.compose(lv);
                    }
                    leader_ready.push(t);
                    leader_maps.push(acc);
                    leader_home.push(node_of(base));
                }
                // tier 2 — master (leader 0) applies leader maps in order
                let mut state = self.dfa.start;
                let mut t = leader_ready[0];
                for (j, lm) in leader_maps.iter().enumerate() {
                    if j > 0 {
                        let lat = self.latency.sample_between(
                            rng,
                            leader_home[j],
                            leader_home[0],
                        );
                        t = t.max(leader_ready[j] + lat);
                    }
                    state = lm.get(state);
                    t += lookup_us;
                }
                (state, t)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::sequential::SequentialMatcher;
    use crate::speculative::lookahead::tests::{fig6_dfa, random_dfa};
    use crate::util::prop;

    fn syms_for(dfa: &Dfa, rng: &mut Rng, n: usize) -> Vec<u32> {
        (0..n).map(|_| rng.below(dfa.num_symbols as u64) as u32).collect()
    }

    #[test]
    fn prop_cloud_matches_sequential() {
        prop::check("cloud == sequential", 25, |rng| {
            let dfa = random_dfa(rng);
            let n = rng.range_usize(0, 3000);
            let syms = syms_for(&dfa, rng, n);
            let seq = SequentialMatcher::new(&dfa).run_syms(&syms);
            let cluster = ClusterSpec::fast_slow(
                rng.range_usize(0, 3),
                rng.range_usize(1, 3),
            );
            let cm = CloudMatcher::new(&dfa, cluster)
                .lookahead(rng.range_usize(0, 3))
                .seed(rng.next_u64());
            let out = cm.run_syms(&syms);
            assert_eq!(out.final_state, seq.final_state);
            assert_eq!(out.accepted, seq.accepted);
        });
    }

    #[test]
    fn prop_cloud_all_merge_strategies_agree() {
        prop::check("cloud merge strategies agree", 15, |rng| {
            let dfa = random_dfa(rng);
            let n = rng.range_usize(10, 2000);
            let syms = syms_for(&dfa, rng, n);
            let cluster = ClusterSpec::homogeneous(3);
            let mk = |strat| {
                CloudMatcher::new(&dfa, ClusterSpec::homogeneous(3))
                    .merge_strategy(strat)
                    .lookahead(2)
                    .seed(7)
                    .run_syms(&syms)
                    .final_state
            };
            let _ = cluster;
            let a = mk(MergeStrategy::Sequential);
            let b = mk(MergeStrategy::BinaryTree);
            let c = mk(MergeStrategy::Hierarchical { cores_per_node: 15 });
            assert!(a == b && b == c);
        });
    }

    #[test]
    fn hierarchical_beats_tree_and_sequential_on_ec2_latency() {
        // the paper's §5.2 finding, for a large cluster
        let dfa = fig6_dfa();
        let mut rng = Rng::new(21);
        let syms = syms_for(&dfa, &mut rng, 4_000_000);
        let run = |strat| {
            CloudMatcher::new(&dfa, ClusterSpec::homogeneous(20))
                .merge_strategy(strat)
                .lookahead(2)
                .seed(99)
                .run_syms(&syms)
                .makespan_us
        };
        let hier = run(MergeStrategy::Hierarchical { cores_per_node: 15 });
        let seq = run(MergeStrategy::Sequential);
        let tree = run(MergeStrategy::BinaryTree);
        assert!(hier < seq, "hier {hier} !< seq {seq}");
        assert!(hier < tree, "hier {hier} !< tree {tree}");
    }

    #[test]
    fn comm_ratio_decreases_with_input_size() {
        // Fig. 19: longer inputs de-emphasize constant comm costs
        let dfa = fig6_dfa();
        let mut rng = Rng::new(22);
        let mut run = |n: usize| {
            let syms = syms_for(&dfa, &mut rng, n);
            CloudMatcher::new(&dfa, ClusterSpec::homogeneous(10))
                .lookahead(2)
                .seed(5)
                .run_syms(&syms)
                .comm_ratio()
        };
        let small = run(100_000);
        let large = run(10_000_000);
        assert!(large < small, "ratio large {large} !< small {small}");
    }

    #[test]
    fn preemption_hurts_without_idle_core() {
        let dfa = fig6_dfa();
        let mut rng = Rng::new(23);
        let syms = syms_for(&dfa, &mut rng, 2_000_000);
        let safe = CloudMatcher::new(&dfa, ClusterSpec::homogeneous(4))
            .lookahead(1)
            .seed(11)
            .run_syms(&syms);
        let risky = CloudMatcher::new(
            &dfa,
            ClusterSpec::homogeneous(4).allocate_all_cores(),
        )
        .lookahead(1)
        .seed(11)
        .run_syms(&syms);
        // preempted worker (10× slower) dominates the makespan
        assert!(risky.makespan_us > safe.makespan_us * 2.0,
                "risky {} safe {}", risky.makespan_us, safe.makespan_us);
    }

    #[test]
    fn load_balance_cv_small_table3() {
        // Table 3: ~1 % average proportional stddev
        let dfa = fig6_dfa();
        let mut rng = Rng::new(24);
        let syms = syms_for(&dfa, &mut rng, 4_000_000);
        // r=1 on the Fig. 6 DFA: every runtime set hits I_max exactly
        // (|I_a| = |I_b| = 2), so per-worker times should be near-equal.
        // (With deeper lookahead, per-chunk sets vary below I_max and the
        // partition's worst-case sizing leaves slack — same as the paper,
        // whose Table 3 CVs are driven by suffix-set concentration.)
        let out = CloudMatcher::new(&dfa, ClusterSpec::fast_slow(4, 1))
            .lookahead(1)
            .seed(13)
            .run_syms(&syms);
        assert!(out.balance_cv() < 0.08, "cv {}", out.balance_cv());
    }

    #[test]
    fn speedup_positive_and_bounded() {
        let dfa = fig6_dfa();
        let mut rng = Rng::new(25);
        let syms = syms_for(&dfa, &mut rng, 8_000_000);
        let out = CloudMatcher::new(&dfa, ClusterSpec::homogeneous(20))
            .lookahead(2)
            .run_syms(&syms);
        let s = out.speedup();
        let p = 300.0;
        assert!(s > 1.0, "speedup {s}");
        assert!(s <= 1.0 + p, "speedup {s} exceeds |P|");
    }
}
