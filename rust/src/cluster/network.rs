//! Message latency model, parameterized with the paper's own EC2
//! measurements (§5.2): inter-node L-vector transfer 362 µs (σ 3.6 %),
//! intra-node 2.68 µs (σ 0.14 %).  Latencies are sampled from truncated
//! normal distributions; the large inter/intra gap is exactly what makes
//! the 2-tier hierarchical merge win (Fig. 9).

use crate::util::rng::Rng;

/// Latency distribution parameters of the simulated cluster, in µs.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    /// mean inter-node L-vector transfer latency, µs
    pub inter_mean_us: f64,
    /// inter-node stddev as a fraction of the mean
    pub inter_sd_frac: f64,
    /// mean intra-node (shared-memory) transfer latency, µs
    pub intra_mean_us: f64,
    /// intra-node stddev as a fraction of the mean
    pub intra_sd_frac: f64,
    /// per-message fixed software overhead (MPI stack), µs
    pub per_msg_overhead_us: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel {
            inter_mean_us: 362.0,
            inter_sd_frac: 0.036,
            intra_mean_us: 2.68,
            intra_sd_frac: 0.0014,
            per_msg_overhead_us: 0.5,
        }
    }
}

impl LatencyModel {
    /// A local-cluster model (for contrast experiments): low, stable
    /// inter-node latency.
    pub fn local_cluster() -> Self {
        LatencyModel {
            inter_mean_us: 20.0,
            inter_sd_frac: 0.01,
            intra_mean_us: 2.68,
            intra_sd_frac: 0.0014,
            per_msg_overhead_us: 0.5,
        }
    }

    /// Sample one inter-node message latency, µs.
    pub fn sample_inter(&self, rng: &mut Rng) -> f64 {
        sample_pos(rng, self.inter_mean_us, self.inter_sd_frac)
            + self.per_msg_overhead_us
    }

    /// Sample one intra-node message latency, µs.
    pub fn sample_intra(&self, rng: &mut Rng) -> f64 {
        sample_pos(rng, self.intra_mean_us, self.intra_sd_frac)
            + self.per_msg_overhead_us
    }

    /// Latency between two workers given their node ids.
    pub fn sample_between(
        &self,
        rng: &mut Rng,
        node_a: usize,
        node_b: usize,
    ) -> f64 {
        if node_a == node_b {
            self.sample_intra(rng)
        } else {
            self.sample_inter(rng)
        }
    }
}

fn sample_pos(rng: &mut Rng, mean: f64, sd_frac: f64) -> f64 {
    let v = rng.gauss_ms(mean, mean * sd_frac);
    v.max(mean * 0.1) // truncate absurd tail draws
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn samples_match_paper_parameters() {
        let m = LatencyModel::default();
        let mut rng = Rng::new(100);
        let inter: Vec<f64> =
            (0..20_000).map(|_| m.sample_inter(&mut rng)).collect();
        let intra: Vec<f64> =
            (0..20_000).map(|_| m.sample_intra(&mut rng)).collect();
        let im = stats::mean(&inter);
        assert!((im - 362.5).abs() < 1.0, "inter mean {im}");
        assert!((stats::stddev(&inter) / 362.0 - 0.036).abs() < 0.005);
        assert!((stats::mean(&intra) - 3.18).abs() < 0.1);
        // the two regimes are separated by two orders of magnitude
        assert!(stats::mean(&inter) / stats::mean(&intra) > 100.0);
    }

    #[test]
    fn between_dispatches_on_node() {
        let m = LatencyModel::default();
        let mut rng = Rng::new(3);
        let same = m.sample_between(&mut rng, 2, 2);
        let diff = m.sample_between(&mut rng, 2, 3);
        assert!(same < 10.0 && diff > 100.0);
    }

    #[test]
    fn samples_always_positive() {
        let m = LatencyModel::default();
        let mut rng = Rng::new(4);
        for _ in 0..10_000 {
            assert!(m.sample_inter(&mut rng) > 0.0);
            assert!(m.sample_intra(&mut rng) > 0.0);
        }
    }
}
