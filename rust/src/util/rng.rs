//! Deterministic PRNG: SplitMix64 core with convenience samplers.
//!
//! Used by workload generators, the cluster latency model and the property
//! tests.  Deterministic seeding keeps every experiment reproducible.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; the canonical
/// seed-expander.  Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// A deterministic stream seeded with `seed`.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, bound).
    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn gauss(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-12 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    pub fn gauss_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gauss()
    }

    /// A uniformly chosen element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-thread determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// The base seed a randomized test or workload generator should use:
/// `SPECDFA_TEST_SEED` from the environment when set (decimal or
/// `0x`-prefixed hex, `_` separators allowed), otherwise `default`.
///
/// This is the replay half of the seed-plumbing contract: every suite
/// that derives its corpus from a seed prints the value it used on
/// entry (so a CI failure names it), and re-running with
/// `SPECDFA_TEST_SEED=<printed value>` reproduces the exact corpus.
/// A malformed value falls back to `default` rather than aborting the
/// suite.
pub fn test_seed(default: u64) -> u64 {
    seed_from_env("SPECDFA_TEST_SEED").unwrap_or(default)
}

/// Parse a seed from environment variable `var` (decimal or `0x` hex).
pub fn seed_from_env(var: &str) -> Option<u64> {
    let raw = std::env::var(var).ok()?;
    let raw = raw.trim().replace('_', "");
    let parsed = match raw.strip_prefix("0x").or_else(|| raw.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => raw.parse(),
    };
    parsed.ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for bound in [1u64, 2, 3, 7, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(5);
        let mut b = a.fork();
        let mut c = a.fork();
        assert_ne!(b.next_u64(), c.next_u64());
    }

    #[test]
    fn env_seed_parsing() {
        // one env var per assertion, names unique to this test so the
        // process-global environment races with no other test
        std::env::set_var("SPECDFA_RNG_T1", "12345");
        assert_eq!(seed_from_env("SPECDFA_RNG_T1"), Some(12345));
        std::env::set_var("SPECDFA_RNG_T2", "0xD1FF_2024");
        assert_eq!(seed_from_env("SPECDFA_RNG_T2"), Some(0xD1FF_2024));
        std::env::set_var("SPECDFA_RNG_T3", " 0XABC ");
        assert_eq!(seed_from_env("SPECDFA_RNG_T3"), Some(0xABC));
        std::env::set_var("SPECDFA_RNG_T4", "not-a-seed");
        assert_eq!(seed_from_env("SPECDFA_RNG_T4"), None);
        assert_eq!(seed_from_env("SPECDFA_RNG_UNSET_VAR"), None);
    }
}
