//! Adversarial, trace-driven workload generation — the harness behind
//! `specdfa bench --suite adversarial` and `tests/adversarial.rs`.
//!
//! Three seeded generators compose into a request trace:
//!
//!  * [`Zipf`] — skewed pattern popularity over a configurable pool,
//!    stressing the serve loop's LRU pattern cache and outcome memo
//!    (a hot head that must hit, a long tail that must not thrash it);
//!  * [`HeavyTailSizes`] — Pareto-distributed input sizes *straddling*
//!    [`crate::engine::serve::ServeConfig::probe_max_bytes`], so one
//!    trace exercises both scheduling classes and the probe/scan
//!    aging machinery between them;
//!  * [`trace`] — bursty open-loop arrivals (geometric burst lengths,
//!    exponential inter-burst gaps), the arrival shape under which
//!    bounded-queue admission and the PR 5 starvation bound actually
//!    bind.
//!
//! A separate factory builds *pathological automata* — the structural
//! worst cases PaREM (arXiv 1412.1741) identifies for parallel
//! matching, plus the ReDoS patterns (arXiv 1110.1716's insomnia
//! taxonomy) the backtracking baseline must survive:
//!
//!  * [`permutation_dfa`] — every symbol acts as a permutation of the
//!    state set, so every word map is a bijection: `I_max,r = |Q|` at
//!    every lookahead depth (γ = 1, Eq. 18's worst case), and
//!    speculative chains **never** converge, defeating collapsing;
//!  * [`dense_frontier_dfa`] — a uniformly random complete transition
//!    table: large reachable frontier, mediocre γ, the "dense
//!    near-complete automaton" case;
//!  * [`sink_heavy_dfa`] — an anchored needle chain where every
//!    off-needle byte falls into a dead sink: tiny γ, instant chain
//!    convergence — the opposite structural extreme;
//!  * ReDoS regexes (`(a|a)*b`-shaped) whose DFAs are trivial but
//!    whose backtracking cost is exponential — they must terminate
//!    with a budget error, never hang.
//!
//! [`replay_trace`] closes the loop: it replays a trace against a live
//! [`Server`], checks every served verdict against the sequential
//! reference, and returns the final [`ServeStats`] so callers can
//! assert the PR 5 invariants (starvation bound, depth bound,
//! snapshot-consistent counters) under adversarial load.
//!
//! Everything is deterministic by seed; suites derive theirs from
//! [`crate::util::rng::test_seed`] so `SPECDFA_TEST_SEED` replays a CI
//! failure exactly.

use std::sync::mpsc::channel;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::automata::{grail, Dfa};
use crate::engine::serve::{ServeConfig, ServeError, ServeStats, Server, Ticket};
use crate::engine::{CompiledMatcher, Engine, Matcher, Pattern};
use crate::util::bench::percentile;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------
// popularity + size + arrival generators
// ---------------------------------------------------------------------

/// Zipfian sampler over ranks `0..k`: rank `r` is drawn with
/// probability proportional to `1 / (r+1)^skew`.  `skew = 0` is
/// uniform; `skew ≈ 1` is the classic web-request shape; larger skews
/// concentrate the mass on the head of the pool.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Sampler over `k` ranks with exponent `skew` (`k` is clamped to
    /// ≥ 1).
    pub fn new(k: usize, skew: f64) -> Zipf {
        let k = k.max(1);
        let mut cdf = Vec::with_capacity(k);
        let mut total = 0.0f64;
        for rank in 1..=k {
            total += 1.0 / (rank as f64).powf(skew);
            cdf.push(total);
        }
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw one rank in `0..k`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf
            .partition_point(|&c| c < u)
            .min(self.cdf.len() - 1)
    }
}

/// Pareto (power-law) input sizes positioned to *straddle* the serve
/// loop's probe/scan boundary: most draws are probe-sized, a heavy
/// tail of draws are scans several times `probe_max_bytes` long.
pub struct HeavyTailSizes {
    /// Pareto scale `x_m` (the minimum of the unclamped distribution)
    pub scale: f64,
    /// Pareto tail exponent α (smaller = heavier tail)
    pub alpha: f64,
    /// hard floor on a drawn size
    pub min: usize,
    /// hard ceiling on a drawn size (keeps a single draw from eating
    /// the whole test budget)
    pub max: usize,
}

impl HeavyTailSizes {
    /// The canonical adversarial shape for a given probe/scan boundary:
    /// `x_m = probe_max/8`, `α = 1.16` (the classic "80/20" exponent),
    /// capped at `8 × probe_max`.  Roughly 9 % of draws land above
    /// `probe_max_bytes` — enough scans to age, enough probes to flood.
    pub fn straddling(probe_max_bytes: usize) -> HeavyTailSizes {
        HeavyTailSizes {
            scale: (probe_max_bytes / 8).max(1) as f64,
            alpha: 1.16,
            min: 16,
            max: probe_max_bytes.saturating_mul(8).max(64),
        }
    }

    /// Draw one size in bytes.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64().max(1e-12);
        let x = self.scale / u.powf(1.0 / self.alpha);
        (x as usize).clamp(self.min, self.max)
    }

    /// Expected fraction of draws strictly above `bytes` (before
    /// clamping): `(x_m / bytes)^α`.
    pub fn tail_fraction(&self, bytes: usize) -> f64 {
        if (bytes as f64) <= self.scale {
            return 1.0;
        }
        (self.scale / bytes as f64).powf(self.alpha)
    }
}

/// One arrival in a generated trace.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// arrival time offset from the trace start, in microseconds
    /// (events inside one burst share an offset)
    pub at_us: u64,
    /// rank of the pattern in the pool (Zipf-distributed; callers
    /// index their pool with `pattern % pool.len()`)
    pub pattern: usize,
    /// input length in bytes (heavy-tail-distributed)
    pub len: usize,
}

/// Shape of a generated trace.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// total number of requests
    pub requests: usize,
    /// pattern-pool size the Zipf sampler ranks over
    pub pool: usize,
    /// Zipf exponent (0 = uniform popularity)
    pub skew: f64,
    /// the probe/scan boundary sizes straddle
    pub probe_max_bytes: usize,
    /// mean burst length (arrivals sharing one instant)
    pub burst: usize,
    /// mean inter-burst gap in microseconds (exponential)
    pub gap_us: u64,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig {
            requests: 400,
            pool: 32,
            skew: 1.1,
            probe_max_bytes: 1 << 10,
            burst: 16,
            gap_us: 400,
        }
    }
}

/// Generate a bursty open-loop arrival trace: bursts of
/// uniformly-jittered length (mean [`TraceConfig::burst`]) separated
/// by exponential gaps (mean [`TraceConfig::gap_us`]), each event
/// carrying a Zipf-ranked pattern and a heavy-tailed input size.
/// Deterministic by `seed`.
pub fn trace(cfg: &TraceConfig, seed: u64) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(cfg.pool, cfg.skew);
    let sizes = HeavyTailSizes::straddling(cfg.probe_max_bytes);
    let mut at = 0u64;
    let mut out = Vec::with_capacity(cfg.requests);
    while out.len() < cfg.requests {
        // burst length: uniform on 1..=2·mean (mean = cfg.burst)
        let burst = 1 + rng.usize_below(cfg.burst.max(1) * 2);
        for _ in 0..burst {
            if out.len() >= cfg.requests {
                break;
            }
            out.push(TraceEvent {
                at_us: at,
                pattern: zipf.sample(&mut rng),
                len: sizes.sample(&mut rng),
            });
        }
        // open-loop gap: exponential with the configured mean — the
        // arrival process never waits for service completions
        let u = rng.f64().max(1e-12);
        at += (-u.ln() * cfg.gap_us as f64) as u64 + 1;
    }
    out
}

// ---------------------------------------------------------------------
// pathological-automata factory
// ---------------------------------------------------------------------

/// Every symbol is a random permutation of the state set, so every
/// word acts as a bijection on `Q`: `I_max,r = |Q|` at every lookahead
/// depth (γ = 1 exactly — Eq. 18's structural worst case) and two
/// speculative chains can never converge, defeating collapse entirely.
/// Roughly half the states accept, so random inputs exercise both
/// verdicts.  `symbols ≤ 256` required.
pub fn permutation_dfa(states: u32, symbols: u32, seed: u64) -> Dfa {
    assert!(states >= 1 && (1..=256).contains(&symbols));
    let mut rng = Rng::new(seed);
    let mut table = vec![0u32; (states * symbols) as usize];
    for s in 0..symbols {
        let mut perm: Vec<u32> = (0..states).collect();
        rng.shuffle(&mut perm);
        for q in 0..states {
            table[(q * symbols + s) as usize] = perm[q as usize];
        }
    }
    let accepting: Vec<bool> = (0..states).map(|q| q % 2 == 0).collect();
    Dfa::new(states, symbols, 0, accepting, table, mod_classes(symbols))
}

/// A uniformly random complete transition table: the "dense
/// near-complete automaton" with a large reachable frontier (the PaREM
/// worst case for frontier-based parallel matching).  About one state
/// in eight accepts (at least one always does).
pub fn dense_frontier_dfa(states: u32, symbols: u32, seed: u64) -> Dfa {
    assert!(states >= 1 && (1..=256).contains(&symbols));
    let mut rng = Rng::new(seed);
    let table: Vec<u32> = (0..states * symbols)
        .map(|_| rng.below(states as u64) as u32)
        .collect();
    let mut accepting: Vec<bool> =
        (0..states).map(|_| rng.below(8) == 0).collect();
    if !accepting.iter().any(|&a| a) {
        let forced = rng.below(states as u64) as usize;
        accepting[forced] = true;
    }
    Dfa::new(states, symbols, 0, accepting, table, mod_classes(symbols))
}

/// An anchored needle chain with a dead sink: state `q < chain` steps
/// to `q+1` on the one needle symbol and to the sink on everything
/// else; the accept state (chain completed) absorbs.  γ is tiny —
/// after a few symbols almost every speculative chain sits in the sink
/// or the accept state — so this is the *best*-case structural extreme
/// that bounds the other end of the sweep.  Returns the DFA and the
/// needle bytes (a guaranteed-accept witness prefix).
pub fn sink_heavy_dfa(chain: u32, symbols: u32, seed: u64) -> (Dfa, Vec<u8>) {
    assert!(chain >= 1 && (2..=256).contains(&symbols));
    let states = chain + 2;
    let accept = chain;
    let sink = chain + 1;
    let mut rng = Rng::new(seed);
    let needle: Vec<u32> =
        (0..chain).map(|_| rng.below(symbols as u64) as u32).collect();
    let mut table = vec![0u32; (states * symbols) as usize];
    for q in 0..states {
        for s in 0..symbols {
            let to = if q < chain {
                if s == needle[q as usize] {
                    q + 1
                } else {
                    sink
                }
            } else if q == accept {
                accept
            } else {
                sink
            };
            table[(q * symbols + s) as usize] = to;
        }
    }
    let mut accepting = vec![false; states as usize];
    accepting[accept as usize] = true;
    let witness: Vec<u8> = needle.iter().map(|&s| s as u8).collect();
    (
        Dfa::new(states, symbols, 0, accepting, table, mod_classes(symbols)),
        witness,
    )
}

/// Byte classes for a synthetic dense-symbol DFA: byte `b` maps to
/// symbol `b mod symbols`, so any byte stream drives the automaton and
/// bytes `0..symbols` hit each symbol exactly.
fn mod_classes(symbols: u32) -> [u8; 256] {
    let mut classes = [0u8; 256];
    for (b, class) in classes.iter_mut().enumerate() {
        *class = (b as u32 % symbols) as u8;
    }
    classes
}

/// One entry of the pathological corpus: a pattern, the byte alphabet
/// adversarial inputs for it should be drawn from, an optional
/// guaranteed-accept witness (planted by the differential suite), and
/// whether the AST comparators (backtracking / grep-like) can compile
/// it at all.
pub struct AdversarialCase {
    /// scenario name (stable across runs; used as the bench workload)
    pub name: String,
    /// the pattern under test
    pub pattern: Pattern,
    /// bytes random inputs should be drawn from so the DFA actually
    /// moves through its state space
    pub alphabet: Vec<u8>,
    /// a byte string guaranteed to be accepted when planted as a
    /// prefix (sink-heavy chains) or substring (search patterns)
    pub witness: Option<Vec<u8>>,
    /// whether the AST engines (backtrack / grep) can run this case —
    /// false for raw Grail automata and anchored patterns
    pub ast_safe: bool,
}

/// The pathological corpus: permutation (γ = 1), dense-frontier and
/// sink-heavy automata at several sizes, ReDoS regexes, and anchored
/// patterns.  Deterministic by `seed`; sub-seeds fork from it so cases
/// are independent.
pub fn pathological_corpus(seed: u64) -> Vec<AdversarialCase> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for (q, s) in [(16u32, 4u32), (64, 8), (256, 16)] {
        let dfa = permutation_dfa(q, s, rng.next_u64());
        out.push(AdversarialCase {
            name: format!("perm-q{q}"),
            pattern: Pattern::Grail(grail::to_grail(&dfa)),
            alphabet: (0..s as u8).collect(),
            witness: None,
            ast_safe: false,
        });
    }
    for (q, s) in [(128u32, 8u32), (512, 16)] {
        let dfa = dense_frontier_dfa(q, s, rng.next_u64());
        out.push(AdversarialCase {
            name: format!("dense-q{q}"),
            pattern: Pattern::Grail(grail::to_grail(&dfa)),
            alphabet: (0..s as u8).collect(),
            witness: None,
            ast_safe: false,
        });
    }
    for (chain, s) in [(30u32, 8u32), (100, 12)] {
        let (dfa, witness) = sink_heavy_dfa(chain, s, rng.next_u64());
        out.push(AdversarialCase {
            name: format!("sink-q{}", chain + 2),
            pattern: Pattern::Grail(grail::to_grail(&dfa)),
            alphabet: (0..s as u8).collect(),
            witness: Some(witness),
            ast_safe: false,
        });
    }
    // ReDoS: trivial DFAs, exponential backtracking — the AST engines
    // must answer with a budget error, never a hang
    for (name, pat, witness) in [
        ("redos-alt", "(a|a)*b", &b"aab"[..]),
        ("redos-nest", "(a+)+b", &b"ab"[..]),
        ("redos-poly", "(ab|a)*c", &b"abc"[..]),
    ] {
        out.push(AdversarialCase {
            name: name.to_string(),
            pattern: Pattern::Regex(pat.to_string()),
            alphabet: b"ab".to_vec(),
            witness: Some(witness.to_vec()),
            ast_safe: true,
        });
    }
    // anchored cases (DFA engines only: the AST comparators refuse ^/$)
    out.push(AdversarialCase {
        name: "anchored-start".to_string(),
        pattern: Pattern::Regex("^(ab|cd)+e".to_string()),
        alphabet: b"abcde".to_vec(),
        witness: None,
        ast_safe: false,
    });
    out.push(AdversarialCase {
        name: "anchored-exact".to_string(),
        pattern: Pattern::RegexExact("(a|b)*abb".to_string()),
        alphabet: b"ab".to_vec(),
        // no witness: the accept condition is a *suffix* ("ends in
        // abb"), which random {a,b} inputs hit 1 time in 8 anyway
        witness: None,
        ast_safe: false,
    });
    out
}

// ---------------------------------------------------------------------
// serve-loop stress driver
// ---------------------------------------------------------------------

/// Client-observed latency percentiles for one scheduling class, in
/// microseconds.  Latency is measured submit → reply received by a
/// dedicated waiter thread, so it includes queueing, aging and
/// preemption — the number a remote client would see, not the worker's
/// service time.  Nearest-rank percentiles via
/// [`crate::util::bench::percentile`].
#[derive(Clone, Debug, Default)]
pub struct LatencySummary {
    /// requests observed in this class
    pub count: usize,
    /// median latency, µs
    pub p50_us: f64,
    /// 90th-percentile latency, µs
    pub p90_us: f64,
    /// 99th-percentile latency, µs
    pub p99_us: f64,
    /// worst observed latency, µs
    pub max_us: f64,
}

impl LatencySummary {
    /// Summarize raw per-request latencies (any order); all-zero for an
    /// empty sample.
    pub fn from_samples(mut us: Vec<f64>) -> LatencySummary {
        if us.is_empty() {
            return LatencySummary::default();
        }
        us.sort_by(f64::total_cmp);
        LatencySummary {
            count: us.len(),
            p50_us: percentile(&us, 0.50),
            p90_us: percentile(&us, 0.90),
            p99_us: percentile(&us, 0.99),
            max_us: *us.last().unwrap(),
        }
    }
}

/// What one [`replay_trace`] run observed.
pub struct StressReport {
    /// final serving telemetry (taken after shutdown drained the queue)
    pub stats: ServeStats,
    /// requests refused at admission (`ServeError::Overloaded`)
    pub rejected: usize,
    /// served verdicts that disagreed with the sequential reference —
    /// always 0 unless failure-freedom is broken
    pub mismatches: usize,
    /// requests that streamed any other error back
    pub errors: usize,
    /// total input bytes submitted (throughput accounting)
    pub bytes: u64,
    /// client-observed latency of probe-class requests (input ≤
    /// `probe_max_bytes`)
    pub probe_lat: LatencySummary,
    /// client-observed latency of scan-class requests
    pub scan_lat: LatencySummary,
}

/// Replay a trace against a live [`Server`] and differentially check
/// every served verdict against `Engine::Sequential`.
///
/// Inputs are generated deterministically from `seed` over each
/// case's alphabet (with the case witness planted at position 0 on a
/// third of its events, so accept verdicts occur).  `pace_cap_us`
/// bounds the inter-burst sleep: `0` floods the queue with no pacing
/// (maximum admission pressure); otherwise gaps are honored up to the
/// cap, preserving burstiness while keeping tests fast.
///
/// The returned [`StressReport`] carries the final [`ServeStats`];
/// callers assert the PR 5 bounds on it (`max_bypass_streak` vs
/// `age_limit`, `max_queue_depth` vs `max_queue`, counter
/// consistency).
pub fn replay_trace(
    config: ServeConfig,
    pool: &[AdversarialCase],
    events: &[TraceEvent],
    seed: u64,
    pace_cap_us: u64,
) -> Result<StressReport> {
    anyhow::ensure!(!pool.is_empty(), "replay needs a non-empty pool");
    let mut rng = Rng::new(seed);
    let refs: Vec<CompiledMatcher> = pool
        .iter()
        .map(|case| {
            CompiledMatcher::compile(
                &case.pattern,
                Engine::Sequential,
                config.policy.clone(),
            )
        })
        .collect::<Result<Vec<_>>>()?;

    // materialize inputs + expected verdicts up front, so the replay
    // loop measures serving rather than generation
    struct Job {
        pattern: usize,
        input: Vec<u8>,
        at_us: u64,
        expect: bool,
    }
    let mut jobs = Vec::with_capacity(events.len());
    for ev in events {
        let idx = ev.pattern % pool.len();
        let case = &pool[idx];
        let mut input: Vec<u8> = (0..ev.len)
            .map(|_| case.alphabet[rng.usize_below(case.alphabet.len())])
            .collect();
        if let Some(w) = &case.witness {
            if rng.below(3) == 0 && w.len() <= input.len() {
                input[..w.len()].copy_from_slice(w);
            }
        }
        let expect = refs[idx].run_bytes(&input)?.accepted;
        jobs.push(Job { pattern: idx, input, at_us: ev.at_us, expect });
    }

    let probe_max = config.probe_max_bytes;
    let server = Server::start(config)?;
    let mut bytes = 0u64;
    let mut mismatches = 0usize;
    let mut rejected = 0usize;
    let mut errors = 0usize;
    let mut probe_us: Vec<f64> = Vec::new();
    let mut scan_us: Vec<f64> = Vec::new();

    // a pool of waiter threads observes each reply as it lands, so the
    // recorded latency is submit → reply (queueing included), not
    // "position in a sequential drain loop"
    std::thread::scope(|scope| {
        let (work_tx, work_rx) = channel::<(usize, Ticket, Instant)>();
        let work_rx = Mutex::new(work_rx);
        let work_rx = &work_rx;
        let (done_tx, done_rx) =
            channel::<(usize, f64, std::result::Result<bool, ServeError>)>();
        for _ in 0..jobs.len().clamp(1, 32) {
            let done_tx = done_tx.clone();
            scope.spawn(move || loop {
                let msg = work_rx.lock().unwrap().recv();
                let Ok((idx, ticket, at)) = msg else { break };
                let res = ticket.wait().map(|out| out.accepted);
                let us = at.elapsed().as_secs_f64() * 1e6;
                let _ = done_tx.send((idx, us, res));
            });
        }
        drop(done_tx);

        let mut last_at = jobs.first().map_or(0, |j| j.at_us);
        for (idx, job) in jobs.iter().enumerate() {
            if pace_cap_us > 0 && job.at_us > last_at {
                let gap = (job.at_us - last_at).min(pace_cap_us);
                std::thread::sleep(Duration::from_micros(gap));
            }
            last_at = job.at_us;
            bytes += job.input.len() as u64;
            let at = Instant::now();
            let ticket = server
                .submit(pool[job.pattern].pattern.clone(), job.input.clone());
            let _ = work_tx.send((idx, ticket, at));
        }
        drop(work_tx);

        for (idx, us, res) in done_rx {
            match res {
                Ok(accepted) => {
                    if accepted != jobs[idx].expect {
                        mismatches += 1;
                    }
                }
                Err(ServeError::Overloaded { .. }) => rejected += 1,
                Err(_) => errors += 1,
            }
            if jobs[idx].input.len() <= probe_max {
                probe_us.push(us);
            } else {
                scan_us.push(us);
            }
        }
    });

    let stats = server.shutdown();
    Ok(StressReport {
        stats,
        rejected,
        mismatches,
        errors,
        bytes,
        probe_lat: LatencySummary::from_samples(probe_us),
        scan_lat: LatencySummary::from_samples(scan_us),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::select::DfaProps;
    use crate::engine::serve::{Admission, PriorityPolicy};

    #[test]
    fn zipf_concentrates_with_skew() {
        let mut rng = Rng::new(1);
        let mut head_share = |skew: f64| {
            let z = Zipf::new(64, skew);
            let n = 8000;
            let hits = (0..n).filter(|_| z.sample(&mut rng) == 0).count();
            hits as f64 / n as f64
        };
        let uniform = head_share(0.0);
        let mild = head_share(0.9);
        let steep = head_share(1.6);
        assert!(uniform < 0.05, "uniform head share {uniform}");
        assert!(mild > uniform * 2.0, "mild {mild} vs uniform {uniform}");
        assert!(steep > mild, "steep {steep} vs mild {mild}");
    }

    #[test]
    fn heavy_tail_straddles_the_probe_boundary() {
        let probe_max = 1 << 12;
        let sizes = HeavyTailSizes::straddling(probe_max);
        let mut rng = Rng::new(2);
        let n = 4000;
        let scans = (0..n)
            .filter(|_| sizes.sample(&mut rng) > probe_max)
            .count();
        let frac = scans as f64 / n as f64;
        assert!(
            (0.02..0.30).contains(&frac),
            "scan fraction {frac} out of the straddling band"
        );
        // the analytic tail agrees with the empirical one, loosely
        let expect = sizes.tail_fraction(probe_max);
        assert!((frac - expect).abs() < 0.08, "{frac} vs {expect}");
    }

    #[test]
    fn traces_are_deterministic_and_bursty() {
        let cfg = TraceConfig::default();
        let a = trace(&cfg, 7);
        let b = trace(&cfg, 7);
        assert_eq!(a.len(), cfg.requests);
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.at_us == y.at_us
                && x.pattern == y.pattern
                && x.len == y.len));
        let c = trace(&cfg, 8);
        assert!(a
            .iter()
            .zip(&c)
            .any(|(x, y)| x.pattern != y.pattern || x.len != y.len));
        // bursty: some instant carries more than one arrival, and time
        // still advances across the whole trace
        let same_instant = a.windows(2).filter(|w| w[0].at_us == w[1].at_us);
        assert!(same_instant.count() > 0, "no bursts generated");
        assert!(a.last().unwrap().at_us > a[0].at_us, "time never advanced");
    }

    #[test]
    fn permutation_dfa_has_gamma_one_everywhere() {
        for r in [1usize, 2, 4] {
            let dfa = permutation_dfa(32, 6, 99);
            let props = DfaProps::analyze(&dfa, r);
            assert_eq!(props.i_max, 32, "lookahead r={r} shrank a bijection");
            assert!((props.gamma - 1.0).abs() < 1e-9);
        }
        // each symbol column really is a permutation
        let dfa = permutation_dfa(32, 6, 99);
        for s in 0..6u32 {
            let mut seen = vec![false; 32];
            for q in 0..32u32 {
                seen[dfa.step(q, s) as usize] = true;
            }
            assert!(seen.iter().all(|&x| x), "symbol {s} is not a bijection");
        }
    }

    #[test]
    fn sink_heavy_dfa_is_speculation_friendly_and_accepts_its_witness() {
        let (dfa, witness) = sink_heavy_dfa(30, 8, 5);
        let props = DfaProps::analyze(&dfa, 4);
        assert!(
            props.gamma <= 0.25,
            "sink-heavy gamma {} should be tiny",
            props.gamma
        );
        // the needle prefix reaches the absorbing accept state
        let mut input = witness.clone();
        input.extend_from_slice(&[0, 1, 2, 3]);
        assert!(dfa.accepts_bytes(&input));
        // an off-needle first byte lands in the sink forever
        let mut wrong = witness.clone();
        wrong[0] = (wrong[0] + 1) % 8;
        assert!(!dfa.accepts_bytes(&wrong));
    }

    #[test]
    fn dense_frontier_dfa_keeps_a_large_frontier() {
        let dfa = dense_frontier_dfa(128, 8, 11);
        let props = DfaProps::analyze(&dfa, 4);
        assert!(
            props.i_max > 128 / 8,
            "dense automaton frontier collapsed: I_max {}",
            props.i_max
        );
        assert!(dfa.accepting.iter().any(|&a| a));
    }

    #[test]
    fn corpus_is_deterministic_and_compiles() {
        let corpus = pathological_corpus(0xADE5);
        assert!(corpus.len() >= 10);
        let again = pathological_corpus(0xADE5);
        assert!(corpus
            .iter()
            .zip(&again)
            .all(|(a, b)| a.name == b.name && a.pattern == b.pattern));
        for case in &corpus {
            CompiledMatcher::compile(
                &case.pattern,
                Engine::Sequential,
                Default::default(),
            )
            .unwrap_or_else(|e| panic!("{} failed to compile: {e:#}", case.name));
            assert!(!case.alphabet.is_empty(), "{}", case.name);
        }
    }

    #[test]
    fn replay_smoke_is_failure_free() {
        // a small flood through a bounded queue: every verdict must
        // match sequential and the counters must reconcile
        let pool = vec![
            AdversarialCase {
                name: "lit".into(),
                pattern: Pattern::Regex("(ab|cd)+e".into()),
                alphabet: b"abcde".to_vec(),
                witness: Some(b"abe".to_vec()),
                ast_safe: true,
            },
            AdversarialCase {
                name: "cls".into(),
                pattern: Pattern::Regex("[ab]c[cd]".into()),
                alphabet: b"abcd".to_vec(),
                witness: Some(b"acd".to_vec()),
                ast_safe: true,
            },
        ];
        let events = trace(
            &TraceConfig {
                requests: 60,
                pool: 2,
                skew: 1.0,
                probe_max_bytes: 512,
                burst: 8,
                gap_us: 100,
            },
            3,
        );
        let config = ServeConfig {
            workers: 2,
            max_queue: 16,
            admission: Admission::Block,
            priority: PriorityPolicy::SizeAware,
            probe_max_bytes: 512,
            age_limit: 2,
            calibrate_on_start: false,
            ..ServeConfig::default()
        };
        let report = replay_trace(config, &pool, &events, 17, 0).unwrap();
        assert_eq!(report.mismatches, 0);
        assert_eq!(report.errors, 0);
        assert_eq!(report.rejected, 0, "Block admission never rejects");
        // latency telemetry covers every request, split by class, with
        // sane percentile ordering
        let (p, s) = (&report.probe_lat, &report.scan_lat);
        assert_eq!(p.count + s.count, 60, "{p:?} {s:?}");
        for lat in [p, s] {
            if lat.count > 0 {
                assert!(lat.p50_us > 0.0, "{lat:?}");
                assert!(lat.p50_us <= lat.p90_us, "{lat:?}");
                assert!(lat.p90_us <= lat.p99_us, "{lat:?}");
                assert!(lat.p99_us <= lat.max_us, "{lat:?}");
            }
        }
        let s = &report.stats;
        assert_eq!(s.submitted, 60);
        assert_eq!(s.served + s.failed, s.submitted);
        assert!(s.max_queue_depth <= 16, "depth {}", s.max_queue_depth);
        assert!(
            s.max_bypass_streak <= 2 + 1,
            "streak {} vs age_limit 2 (+1 fused drain credit)",
            s.max_bypass_streak
        );
    }
}
