//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Provides wall-clock timing with warmup + repetition, a fixed-width
//! table printer used by every `rust/benches/*.rs` target to print the
//! rows of the paper's tables and figures, and the machine-readable
//! JSON emitter behind `specdfa bench --json` (the `BENCH_*.json` perf
//! trajectory; schema [`BENCH_SCHEMA`]).

use std::time::Instant;

use super::stats;

/// Time `f` (returning an opaque value to defeat DCE) with warmup.
/// Returns median seconds per iteration.
pub fn time_median<T, F: FnMut() -> T>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    stats::median(&samples)
}

/// Time a single execution.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (f64, T) {
    let t0 = Instant::now();
    let v = f();
    (t0.elapsed().as_secs_f64(), v)
}

/// Nearest-rank percentile of an ascending-sorted, non-empty sample;
/// `p` in `[0, 1]` (0.5 = median, 0.99 = p99).  Shared by the serve
/// and adversarial latency suites so every `BENCH_*.json` percentile
/// means the same thing.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample");
    let idx = ((sorted.len() - 1) as f64 * p.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

/// Simple fixed-width table, printed in the style of the paper's tables.
pub struct Table {
    /// table caption
    pub title: String,
    /// column headers
    pub headers: Vec<String>,
    /// formatted rows
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given caption and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render the table to a fixed-width string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
        println!();
    }
}

/// Format a speedup the way the paper does: speed-downs as negative factors
/// ("conventional denotation for a 2x speed-down is 1/2 but we use -2").
pub fn fmt_speedup(s: f64) -> String {
    if s >= 1.0 || s <= 0.0 {
        format!("{s:.1}x")
    } else {
        format!("{:.1}x", -1.0 / s)
    }
}

/// Schema identifier of the `specdfa bench --json` output.  Bump only
/// with a migration note in docs/ARCHITECTURE.md — CI's bench smoke job
/// fails on schema drift.
pub const BENCH_SCHEMA: &str = "specdfa-bench-v1";

/// One benchmark measurement destined for the machine-readable JSON.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// suite the record belongs to ("kernels" / "engines")
    pub suite: String,
    /// workload name (pattern + input distribution)
    pub workload: String,
    /// kernel or engine tier measured (e.g. "seq_u16", "x8_u8", "spec")
    pub kernel: String,
    /// SBase storage width, where the tier pins one ("u8"/"u16"/"u32")
    pub width: Option<String>,
    /// SBase table bytes (the hot working set), where applicable
    pub table_bytes: Option<usize>,
    /// input length in symbols
    pub n_syms: usize,
    /// timed repetitions (median taken)
    pub reps: usize,
    /// median seconds per iteration
    pub secs_per_iter: f64,
    /// symbol steps per second executed by the tier
    pub syms_per_sec: f64,
    /// total symbol steps the engine actually matched, where tracked
    pub syms_matched: Option<u64>,
    /// convergence collapses, where tracked
    pub collapses: Option<u64>,
}

/// Escape a string for a JSON string literal (control chars, quotes,
/// backslashes).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn json_opt_u64(v: Option<u64>) -> String {
    match v {
        Some(x) => x.to_string(),
        None => "null".to_string(),
    }
}

impl BenchRecord {
    fn to_json(&self) -> String {
        format!(
            "{{\"suite\":\"{}\",\"workload\":\"{}\",\"kernel\":\"{}\",\
             \"width\":{},\"table_bytes\":{},\"n_syms\":{},\"reps\":{},\
             \"secs_per_iter\":{},\"syms_per_sec\":{},\
             \"syms_matched\":{},\"collapses\":{}}}",
            json_escape(&self.suite),
            json_escape(&self.workload),
            json_escape(&self.kernel),
            match &self.width {
                Some(w) => format!("\"{}\"", json_escape(w)),
                None => "null".to_string(),
            },
            match self.table_bytes {
                Some(b) => b.to_string(),
                None => "null".to_string(),
            },
            self.n_syms,
            self.reps,
            json_f64(self.secs_per_iter),
            json_f64(self.syms_per_sec),
            json_opt_u64(self.syms_matched),
            json_opt_u64(self.collapses),
        )
    }
}

/// Render the full `specdfa bench` JSON document.  `host_syms_per_us`
/// is the §4.1 calibration rate (None when profiling was skipped);
/// `provenance` records how the numbers were produced.
pub fn render_bench_json(
    suite: &str,
    quick: bool,
    host_syms_per_us: Option<f64>,
    provenance: &str,
    records: &[BenchRecord],
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"schema\": \"{BENCH_SCHEMA}\",\n"));
    out.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(suite)));
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!(
        "  \"host\": {{\"profile_syms_per_us\": {}}},\n",
        match host_syms_per_us {
            Some(r) => json_f64(r),
            None => "null".to_string(),
        }
    ));
    out.push_str(&format!(
        "  \"provenance\": \"{}\",\n",
        json_escape(provenance)
    ));
    out.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str("    ");
        out.push_str(&r.to_json());
        if i + 1 < records.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 51.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[7.5], 0.99), 7.5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("333"));
        let lines: Vec<&str> = r.lines().skip(1).collect();
        assert_eq!(lines[1].len(), lines[2].len().max(lines[1].len()));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn speedup_formatting_paper_convention() {
        assert_eq!(fmt_speedup(2.0), "2.0x");
        assert_eq!(fmt_speedup(0.5), "-2.0x");
        assert_eq!(fmt_speedup(1.0), "1.0x");
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(1, 3, || (0..1000).sum::<u64>());
        assert!(t >= 0.0);
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("x\ny\t"), "x\\ny\\t");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn bench_json_shape() {
        let rec = BenchRecord {
            suite: "kernels".to_string(),
            workload: "pcre-small".to_string(),
            kernel: "seq_u8".to_string(),
            width: Some("u8".to_string()),
            table_bytes: Some(64),
            n_syms: 1000,
            reps: 3,
            secs_per_iter: 0.5,
            syms_per_sec: 2000.0,
            syms_matched: None,
            collapses: None,
        };
        let doc =
            render_bench_json("kernels", true, Some(500.0), "test", &[rec]);
        assert!(doc.contains("\"schema\": \"specdfa-bench-v1\""));
        assert!(doc.contains("\"suite\": \"kernels\""));
        assert!(doc.contains("\"quick\": true"));
        assert!(doc.contains("\"profile_syms_per_us\": 500"));
        assert!(doc.contains("\"kernel\":\"seq_u8\""));
        assert!(doc.contains("\"width\":\"u8\""));
        assert!(doc.contains("\"syms_matched\":null"));
        // crude well-formedness: balanced braces/brackets, no trailing
        // comma before the closing bracket
        let braces =
            doc.matches('{').count() as i64 - doc.matches('}').count() as i64;
        assert_eq!(braces, 0);
        assert!(!doc.contains(",\n  ]"));
        // non-finite numbers must degrade to null, not break the JSON
        let nan = BenchRecord {
            secs_per_iter: f64::NAN,
            syms_per_sec: f64::INFINITY,
            ..BenchRecord {
                suite: "kernels".into(),
                workload: "w".into(),
                kernel: "k".into(),
                width: None,
                table_bytes: None,
                n_syms: 0,
                reps: 0,
                secs_per_iter: 0.0,
                syms_per_sec: 0.0,
                syms_matched: Some(7),
                collapses: Some(1),
            }
        };
        let doc = render_bench_json("kernels", false, None, "t", &[nan]);
        assert!(doc.contains("\"secs_per_iter\":null"));
        assert!(doc.contains("\"syms_per_sec\":null"));
        assert!(doc.contains("\"syms_matched\":7"));
    }
}
