//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Provides wall-clock timing with warmup + repetition and a fixed-width
//! table printer used by every `rust/benches/*.rs` target to print the rows
//! of the paper's tables and figures.

use std::time::Instant;

use super::stats;

/// Time `f` (returning an opaque value to defeat DCE) with warmup.
/// Returns median seconds per iteration.
pub fn time_median<T, F: FnMut() -> T>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    stats::median(&samples)
}

/// Time a single execution.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (f64, T) {
    let t0 = Instant::now();
    let v = f();
    (t0.elapsed().as_secs_f64(), v)
}

/// Simple fixed-width table, printed in the style of the paper's tables.
pub struct Table {
    /// table caption
    pub title: String,
    /// column headers
    pub headers: Vec<String>,
    /// formatted rows
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given caption and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render the table to a fixed-width string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
        println!();
    }
}

/// Format a speedup the way the paper does: speed-downs as negative factors
/// ("conventional denotation for a 2x speed-down is 1/2 but we use -2").
pub fn fmt_speedup(s: f64) -> String {
    if s >= 1.0 || s <= 0.0 {
        format!("{s:.1}x")
    } else {
        format!("{:.1}x", -1.0 / s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("333"));
        let lines: Vec<&str> = r.lines().skip(1).collect();
        assert_eq!(lines[1].len(), lines[2].len().max(lines[1].len()));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn speedup_formatting_paper_convention() {
        assert_eq!(fmt_speedup(2.0), "2.0x");
        assert_eq!(fmt_speedup(0.5), "-2.0x");
        assert_eq!(fmt_speedup(1.0), "1.0x");
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(1, 3, || (0..1000).sum::<u64>());
        assert!(t >= 0.0);
    }
}
