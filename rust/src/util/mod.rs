//! Self-contained utilities (the build is offline; no external crates
//! besides `xla`/`anyhow`): PRNG, statistics, a mini property-testing
//! harness, a mini benchmark harness and a tiny non-cryptographic
//! hasher.

pub mod bench;
pub mod bitset;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod workload;

/// FNV-1a over a byte slice: the request-dedup hash of the serving
/// path's outcome cache.  Non-cryptographic; collisions are further
/// guarded by keying on `(pattern, input length, hash)`.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01B3);
    }
    h
}

#[cfg(test)]
mod fnv_tests {
    use super::fnv1a;

    #[test]
    fn known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a(b"foobar"), 0x85944171F73967E8);
    }

    #[test]
    fn distinguishes_nearby_inputs() {
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abca"));
    }
}
