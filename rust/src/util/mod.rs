//! Self-contained utilities (the build is offline; no external crates
//! besides `xla`/`anyhow`): PRNG, statistics, a mini property-testing
//! harness and a mini benchmark harness.

pub mod bench;
pub mod bitset;
pub mod prop;
pub mod rng;
pub mod stats;
