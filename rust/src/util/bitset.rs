//! Fixed-capacity bitset over DFA states.
//!
//! Initial-state sets (Eq. 11/13) and Hopcroft partitions are sets of
//! states; |Q| reaches ~1300 for PROSITE, so a u64-word bitset is the right
//! representation for images, unions and cardinalities.

/// Fixed-capacity set of small integers (DFA state ids).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct BitSet {
    words: Vec<u64>,
    bits: usize,
}

impl BitSet {
    /// An empty set with capacity for `bits` elements.
    pub fn new(bits: usize) -> Self {
        BitSet { words: vec![0; bits.div_ceil(64)], bits }
    }

    /// The fixed capacity (largest storable element + 1).
    pub fn capacity(&self) -> usize {
        self.bits
    }

    /// Add `i` to the set.
    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.bits);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Remove `i` from the set.
    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.bits);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    /// Whether `i` is in the set.
    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.bits);
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    /// Number of elements (popcount).
    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Whether the set has no elements.
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Remove every element.
    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// In-place union with `other` (equal capacities).
    pub fn union_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.bits, other.bits);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// In-place intersection with `other` (equal capacities).
    pub fn intersect_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.bits, other.bits);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// Iterate the elements in increasing order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Build a set of capacity `bits` from the given elements.
    pub fn from_iter_cap(bits: usize, it: impl IntoIterator<Item = usize>) -> Self {
        let mut s = BitSet::new(bits);
        for i in it {
            s.insert(i);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let mut s = BitSet::new(200);
        assert!(s.is_empty());
        s.insert(0);
        s.insert(63);
        s.insert(64);
        s.insert(199);
        assert_eq!(s.len(), 4);
        assert!(s.contains(63) && s.contains(64));
        assert!(!s.contains(1));
        s.remove(63);
        assert!(!s.contains(63));
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 64, 199]);
    }

    #[test]
    fn union_intersect() {
        let a = BitSet::from_iter_cap(100, [1, 2, 3, 50]);
        let b = BitSet::from_iter_cap(100, [2, 3, 4, 99]);
        let mut u = a.clone();
        u.union_with(&b);
        assert_eq!(u.iter().collect::<Vec<_>>(), vec![1, 2, 3, 4, 50, 99]);
        let mut i = a.clone();
        i.intersect_with(&b);
        assert_eq!(i.iter().collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn eq_and_hash_by_value() {
        let a = BitSet::from_iter_cap(128, [5, 70]);
        let b = BitSet::from_iter_cap(128, [70, 5]);
        assert_eq!(a, b);
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(a);
        assert!(set.contains(&b));
    }
}
