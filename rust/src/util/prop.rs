//! Mini property-testing harness (no proptest crate offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` independent
//! deterministic PRNG streams; on failure it reports the failing case seed
//! so the case replays exactly with `replay(seed, |rng| ...)`.

use super::rng::Rng;

/// Base seed; fixed so CI is deterministic.  Override with
/// `SPECDFA_PROP_SEED`, or with the suite-wide `SPECDFA_TEST_SEED`
/// (both accept decimal or `0x` hex via
/// [`super::rng::seed_from_env`]); the prop-specific variable wins
/// when both are set.
fn base_seed() -> u64 {
    super::rng::seed_from_env("SPECDFA_PROP_SEED")
        .or_else(|| super::rng::seed_from_env("SPECDFA_TEST_SEED"))
        .unwrap_or(0xC0FFEE)
}

/// Number-of-cases multiplier, for soak runs (SPECDFA_PROP_FACTOR=10).
fn factor() -> usize {
    std::env::var("SPECDFA_PROP_FACTOR")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Run `f` over `cases` random cases. `f` should panic (assert!) on failure.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: usize, mut f: F) {
    let base = base_seed();
    for i in 0..cases * factor() {
        let seed = base ^ ((i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {i} (seed {seed:#x}); \
                 replay with util::prop::replay({seed:#x}, ..)"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 below bound", 50, |rng| {
            let b = rng.range_u64(1, 1000);
            assert!(rng.below(b) < b);
        });
    }

    #[test]
    #[should_panic]
    fn reports_failing_property() {
        check("always fails eventually", 10, |rng| {
            assert!(rng.f64() < 0.5, "coin came up heads");
        });
    }
}
