//! Descriptive statistics used by the profiler (median capacities, Eq. 1),
//! the load-balance evaluation (Table 3 stddevs) and the bench harness.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (the paper reports stddev of matching
/// times across cores; population form since all cores are observed).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Coefficient of variation (stddev / mean), the Table 3 metric.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

/// Median; 0 for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Minimum (+∞ for an empty slice).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum (−∞ for an empty slice).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Geometric mean, for aggregating speedups across benchmarks.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(median(&[7.0]), 7.0);
    }

    #[test]
    fn stddev_known() {
        // population stddev of [2,4,4,4,5,5,7,9] is 2
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cv_scale_invariant() {
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 20.0, 30.0];
        assert!((cv(&a) - cv(&b)).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 7.5];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.5);
    }
}
