//! Quickstart for the unified engine API: compile a pattern once, let
//! `Engine::Auto` pick the substrate per request, serve a batch, and
//! verify failure-freedom against the sequential yardstick.
//!
//!     cargo run --release --example quickstart

use specdfa::engine::{
    CompiledMatcher, Engine, EngineKind, ExecPolicy, Matcher, Pattern,
};
use specdfa::speculative::profile::profile_workers;
use specdfa::workload::InputGen;
use specdfa::SequentialMatcher;

fn main() -> anyhow::Result<()> {
    // 1. Pattern -> CompiledMatcher: minimal DFA (Thompson NFA -> subset
    //    construction -> Hopcroft) + structural analysis + every adapter
    //    Engine::Auto can dispatch to, built once.
    let pattern = Pattern::Regex(
        r"GET /[a-z0-9/]{1,16} HTTP/1\.[01]".to_string(),
    );
    let cm = CompiledMatcher::compile(
        &pattern,
        Engine::Auto,
        ExecPolicy::default(),
    )?;
    println!("{}\n", cm.describe());

    // 2. Requests of three very different sizes: Auto dispatches each to
    //    the substrate the (gamma, |Q|, n) thresholds pick.
    let mut gen = InputGen::new(42);
    let probe = gen.ascii_text(2 << 10); // 2 KB health probe
    let mut page = gen.ascii_text(512 << 10); // 512 KB log page
    gen.plant(&mut page, b"GET /index/html HTTP/1.1", 3);
    let mut corpus = gen.ascii_text(16 << 20); // 16 MB corpus scan
    gen.plant(&mut corpus, b"GET /index/html HTTP/1.1", 5);

    for (name, input) in
        [("probe", &probe), ("page", &page), ("corpus", &corpus)]
    {
        let out = cm.run_bytes(input)?;
        let sel = out.selection.as_ref().expect("auto reports why");
        println!("{name:>6} ({:>8} B) -> {}", input.len(), sel);
        println!(
            "        accepted={} makespan={} model-speedup={:.2}x\n",
            out.accepted,
            out.makespan,
            out.model_speedup()
        );

        // 3. Failure-freedom: whatever substrate ran, the result equals
        //    the Listing-1 sequential run.
        let seq = SequentialMatcher::new(cm.dfa()).run_bytes(input);
        assert_eq!(out.accepted, seq.accepted);
        if let Some(fs) = out.final_state {
            assert_eq!(fs, seq.final_state);
        }
    }

    // 4. Batched serving: many inputs, one compiled pattern, per-request
    //    dispatch — the serving-shaped entry point.
    let inputs: Vec<&[u8]> =
        vec![&probe, &page, b"GET /a HTTP/1.0", &corpus];
    let batch = cm.match_many(&inputs);
    assert_eq!(batch.error_count(), 0, "every request has its own slot");
    println!(
        "batch: {} requests, {} B total, {:.1} ms wall",
        batch.outcomes.len(),
        batch.total_syms,
        batch.wall_s * 1e3
    );
    for (kind, count) in batch.by_engine() {
        println!("  {count} request(s) served by {kind}");
    }
    if cm.props().gamma <= 0.5 {
        assert!(
            batch.by_engine().len() >= 2,
            "mixed sizes must use mixed engines on a structured DFA"
        );
    }

    // 5. Explicit engine choice is one variant away — same API, same
    //    verified result.
    let spec = CompiledMatcher::compile(
        &pattern,
        Engine::Speculative { adaptive: false },
        ExecPolicy { processors: 8, lookahead: 4, ..ExecPolicy::default() },
    )?;
    let out = spec.run_bytes(&page)?;
    assert_eq!(out.engine, EngineKind::Speculative);
    println!(
        "\nexplicit speculative on the page: makespan {} of {} symbols \
         -> {:.2}x",
        out.makespan,
        page.len(),
        out.model_speedup()
    );
    println!("failure-freedom verified across all engines");

    // 6. Corpus-scale inputs go hierarchical: `Engine::Shard` splits one
    //    input across cluster nodes AND each node's cores (two-level
    //    Eq. 1 partition), with the intra-node weights taken from a
    //    *measured* per-worker capacity vector.  `Engine::Auto` picks
    //    this tier by itself past `AutoThresholds::shard_min_n`.
    let cv = profile_workers(4, 2, 1 << 15);
    let shard = CompiledMatcher::compile(
        &pattern,
        Engine::Shard { nodes: 3 },
        ExecPolicy {
            processors: 4,
            lookahead: 4,
            weights: Some(cv.weights()),
            ..ExecPolicy::default()
        },
    )?;
    let out = shard.run_bytes(&corpus)?;
    assert_eq!(out.engine, EngineKind::Shard);
    let seq = SequentialMatcher::new(shard.dfa()).run_bytes(&corpus);
    assert_eq!(out.accepted, seq.accepted);
    println!(
        "hierarchical shard on the corpus (3 nodes x 4 workers, measured \
         capacity vector, skew {:.3}): makespan {} of {} symbols -> \
         {:.2}x",
        cv.skew(),
        out.makespan,
        corpus.len(),
        out.model_speedup()
    );

    // 7. For a long-lived process serving many producers, the async
    //    serving loop (worker threads + coalescing + pattern cache +
    //    capacity-calibrated routing + per-worker capacity vectors) is
    //    the next step: `cargo run --release --example serve`.
    Ok(())
}
