//! Protein signature scanning — the paper's PROSITE/DNA-analysis use case
//! (§1, §6): scan a protein corpus for real PROSITE signatures, comparing
//! the sequential matcher, the speculative parallel matcher, and the
//! ScanProsite-style backtracking engine.
//!
//!     cargo run --release --example protein_scan

use std::time::Instant;

use specdfa::baseline::backtracking::Backtracker;
use specdfa::engine::{
    CompiledMatcher, Engine, ExecPolicy, Matcher,
};
use specdfa::regex::prosite;
use specdfa::util::bench::Table;
use specdfa::workload::{prosite_suite_cached, InputGen};
use specdfa::SequentialMatcher;

fn main() -> anyhow::Result<()> {
    // 2 MB protein "database" with SwissProt-like residue frequencies,
    // with two signatures planted so some patterns hit.
    let mut gen = InputGen::new(7);
    let mut corpus = gen.protein(2 << 20);
    gen.plant(&mut corpus, b"RGD", 4); // PS00016
    gen.plant(&mut corpus, b"LAAAAAALCCCCCCLDDDDDDL", 1); // leucine zipper

    let mut t = Table::new(
        "protein scan: 2 MB corpus, P=8, r=4",
        &["signature", "|Q|", "hit", "seq ms", "spec model ms",
          "backtrack ms"],
    );
    for p in prosite_suite_cached().iter().take(10) {
        let seq = SequentialMatcher::new(&p.dfa);
        let t0 = Instant::now();
        let s = seq.run_bytes(&corpus);
        let seq_ms = t0.elapsed().as_secs_f64() * 1e3;

        // the speculative engine through the unified facade
        let cm = CompiledMatcher::from_dfa(
            p.dfa.clone(),
            Engine::Speculative { adaptive: false },
            ExecPolicy { processors: 8, lookahead: 4, ..Default::default() },
        )?;
        let out = cm.run_bytes(&corpus)?;
        assert_eq!(out.accepted, s.accepted, "failure-freedom");
        let model_ms = seq_ms * out.makespan as f64 / corpus.len() as f64;

        let parsed = prosite::parse(&p.pattern)?;
        let bt = Backtracker::with_fuel(&parsed.ast, 500_000_000);
        let t0 = Instant::now();
        let bt_out = bt.search(&corpus);
        let bt_ms = t0.elapsed().as_secs_f64() * 1e3;
        let bt_cell = match bt_out {
            Some(r) => {
                assert_eq!(r.matched, s.accepted);
                format!("{bt_ms:.1}")
            }
            None => format!(">{bt_ms:.0} (fuel)"),
        };

        t.row(vec![
            p.name.clone(),
            p.q().to_string(),
            s.accepted.to_string(),
            format!("{seq_ms:.1}"),
            format!("{model_ms:.1}"),
            bt_cell,
        ]);
    }
    t.print();
    println!("All parallel results verified against sequential semantics.");
    Ok(())
}
