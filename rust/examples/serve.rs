//! Serving walkthrough: the asynchronous batched serving loop.
//!
//! Demonstrates the pieces `engine::serve` adds over `match_many`:
//!  1. capacity calibration at startup (§4.1 offline profiling) feeding
//!     `Engine::Auto` thresholds,
//!  2. many producer threads submitting `(pattern, input)` requests,
//!  3. same-pattern coalescing behind an LRU compiled-pattern cache,
//!  4. per-request outcome streaming, verified against the synchronous
//!     `match_many` path,
//!  5. bounded admission (backpressure) + size-aware priorities, and a
//!     `ServerHandle` that stays safe across shutdown.
//!
//!     cargo run --release --example serve

use specdfa::engine::{
    Admission, CompiledMatcher, Engine, ExecPolicy, Pattern,
    PriorityPolicy, ServeConfig, ServeError, Server,
};
use specdfa::workload::InputGen;

fn main() -> anyhow::Result<()> {
    // 1. Start the server.  `calibrate_on_start` (default) runs the
    //    offline profiling step, so Auto routing uses this machine's
    //    measured symbol rate instead of the paper-era ballpark.  The
    //    queue is bounded: at 256 queued requests, producers block
    //    until the workers drain space (`Admission::Reject` would shed
    //    load instead), and small probes are scheduled ahead of corpus
    //    scans (`PriorityPolicy::SizeAware`, aged so scans still run).
    let server = Server::start(ServeConfig {
        workers: 4,
        cache_patterns: 16,
        max_queue: 256,
        admission: Admission::Block,
        priority: PriorityPolicy::SizeAware,
        recalibrate_every: 0, // one-shot demo: skip periodic re-profiling
        engine: Engine::Auto,
        ..ServeConfig::default()
    })?;
    let t = server.thresholds();
    println!(
        "calibrated: {:.0} sym/us -> sequential below {} syms, cloud at \
         {}, shard at {}",
        t.calibrated_rate.unwrap_or(0.0),
        t.seq_max_n,
        t.cloud_min_n,
        t.shard_min_n
    );
    if let Some(rates) = server.stats().worker_rates {
        println!(
            "per-worker capacity vector (Eq. 1 weights feed every \
             partition): {:?} sym/us",
            rates.iter().map(|r| r.round()).collect::<Vec<_>>()
        );
    }

    // 2. Three patterns, a shared corpus of requests per pattern.
    let patterns = [
        Pattern::Regex(r"GET /[a-z0-9/]+ HTTP/1\.[01]".to_string()),
        Pattern::Regex("ERROR|FATAL".to_string()),
        Pattern::Prosite("C-x(2)-C-x(3)-[LIVMFYWC].".to_string()),
    ];
    let mut gen = InputGen::new(0x5E12);
    let mut corpora: Vec<Vec<Vec<u8>>> = Vec::new();
    for (i, _) in patterns.iter().enumerate() {
        let mut inputs = Vec::new();
        for k in 0..24 {
            let n = 256 << (k % 5); // mixed sizes: 256 B .. 4 KB
            let mut text = if i == 2 {
                gen.protein(n)
            } else {
                gen.ascii_text(n)
            };
            if k % 3 == 0 && i == 1 {
                gen.plant(&mut text, b"FATAL", 1);
            }
            inputs.push(text);
        }
        corpora.push(inputs);
    }

    // 3. Producer threads submit interleaved; each collects its own
    //    tickets and waits in submission order.
    let outcomes = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (pattern, inputs) in patterns.iter().zip(&corpora) {
            let server = &server;
            handles.push(scope.spawn(move || {
                let tickets: Vec<_> = inputs
                    .iter()
                    .map(|inp| server.submit(pattern.clone(), inp.clone()))
                    .collect();
                tickets
                    .into_iter()
                    .map(|t| t.wait().expect("serve must not fail here"))
                    .collect::<Vec<_>>()
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("producer panicked"))
            .collect::<Vec<_>>()
    });

    // 4. Verify the streamed outcomes against the synchronous path.
    for ((pattern, inputs), served) in
        patterns.iter().zip(&corpora).zip(&outcomes)
    {
        let cm = CompiledMatcher::compile(
            pattern,
            Engine::Auto,
            ExecPolicy::default(),
        )?;
        let refs: Vec<&[u8]> =
            inputs.iter().map(|v| v.as_slice()).collect();
        let direct = cm.match_many(&refs);
        assert_eq!(direct.error_count(), 0);
        assert_eq!(served.len(), direct.outcomes.len());
        for (a, b) in served.iter().zip(direct.ok_outcomes()) {
            assert_eq!(a.accepted, b.accepted);
            assert_eq!(a.final_state, b.final_state);
        }
    }
    println!("streamed outcomes equal the synchronous match_many results");

    // 5. A handle survives shutdown: late submissions resolve with
    //    ShuttingDown instead of hanging on a queue nobody drains.
    let handle = server.handle();
    let stats = server.shutdown();
    println!(
        "served {} requests in {} batches ({:.2} requests/batch); \
         {} compiles for {} patterns, {} cache hits",
        stats.served,
        stats.batches,
        stats.requests_per_batch(),
        stats.compiles,
        3,
        stats.cache_hits
    );
    println!(
        "queue: peak depth {} (bound 256), {} rejected; probe wait mean \
         {:.0} us (max {} us), scan wait mean {:.0} us (max {} us)",
        stats.max_queue_depth,
        stats.rejected,
        stats.probe_wait.mean_us(),
        stats.probe_wait.max_us,
        stats.scan_wait.mean_us(),
        stats.scan_wait.max_us
    );
    assert!(stats.compiles < stats.served, "coalescing + cache must win");
    let late = handle
        .submit(Pattern::Regex("too late".to_string()), &b"x"[..])
        .wait();
    assert_eq!(late.unwrap_err(), ServeError::ShuttingDown);
    println!("late submission resolved with ShuttingDown (no hang)");
    Ok(())
}
