//! End-to-end full-stack driver: proves all three layers compose on a
//! real small workload.
//!
//! Pipeline exercised:
//!   PROSITE pattern text
//!     -> parser -> Thompson NFA -> subset construction -> Hopcroft
//!     -> structural analysis (I_max,r; Eqs. 11-13)
//!     -> L3 multicore speculative match over real threads (Alg. 3)
//!     -> L3 simulated-EC2 cloud match (Fig. 9 merging)
//!     -> L1/L2 vectorized match via the AOT Pallas artifact on PJRT
//!   with every path checked against sequential semantics (Alg. 1).
//!
//! Run (artifacts required: `make artifacts`):
//!     cargo run --release --example e2e_full_stack
//!
//! The summary table is recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use specdfa::cluster::{CloudMatcher, ClusterSpec};
use specdfa::experiments::calibrate::host_syms_per_us;
use specdfa::runtime::pjrt::VectorUnit;
use specdfa::runtime::simd::SimdMatcher;
use specdfa::speculative::lookahead::Lookahead;
use specdfa::speculative::matcher::MatchPlan;
use specdfa::util::bench::Table;
use specdfa::workload::{prosite_suite_cached, InputGen};
use specdfa::SequentialMatcher;

fn main() -> anyhow::Result<()> {
    println!("== specdfa end-to-end full-stack driver ==\n");

    // --- workload: 8 MB protein corpus, real PROSITE signatures ---
    let mut gen = InputGen::new(0xE2E);
    let mut corpus = gen.protein(8 << 20);
    gen.plant(&mut corpus, b"RGD", 8);
    gen.plant(&mut corpus, b"IDLGTTS", 2); // PS00298 HSP70 fragment
    println!("corpus: {} MB protein sequence", corpus.len() >> 20);

    let rate = host_syms_per_us();
    println!("host calibration: {rate:.0} symbols/us\n");

    let vu = std::sync::Arc::new(
        VectorUnit::load(VectorUnit::default_dir(), "lane8_main")
            .map_err(|e| anyhow::anyhow!(
                "{e:#}\n(artifact manifest missing?)"))?,
    );
    println!("vector unit: lane8_main on {} ({} lanes, q<={})\n",
             vu.platform(), vu.spec.lanes, vu.spec.q);

    let mut t = Table::new(
        "end-to-end: sequential vs multicore vs cloud vs vector unit",
        &["signature", "|Q|", "I_max4", "hit", "seq ms",
          "mc speedup (P=40)", "cloud speedup (288c)", "simd instr-speedup",
          "verified"],
    );

    let patterns: Vec<_> = prosite_suite_cached()
        .iter()
        .filter(|p| (p.dfa.num_states as usize) <= vu.spec.q)
        .take(6)
        .collect();
    for p in patterns {
        // structural analysis
        let la = Lookahead::analyze(&p.dfa, 4);

        // L3 sequential (Listing 1) — the measured yardstick
        let seq = SequentialMatcher::new(&p.dfa);
        let t0 = Instant::now();
        let want = seq.run_bytes(&corpus);
        let seq_ms = t0.elapsed().as_secs_f64() * 1e3;

        // L3 multicore speculative match over REAL threads
        let plan = MatchPlan::new(&p.dfa).processors(40).lookahead(4);
        let mc = plan.run(&corpus);
        let mc_speedup =
            corpus.len() as f64 / mc.makespan_syms().max(1) as f64;

        // L3 cloud (simulated EC2, 20 nodes / 288 cores)
        let syms = p.dfa.map_input(&corpus);
        let cloud = CloudMatcher::new(&p.dfa, ClusterSpec::homogeneous(20))
            .lookahead(4)
            .base_rate(rate)
            .run_syms(&syms);

        // L1/L2 vectorized match via PJRT (64 KiB slice — interpret-mode
        // executable; work ratios are the metric, §6.1 methodology)
        let slice = &syms[..(1 << 16).min(syms.len())];
        let want_slice = seq.run_syms(slice);
        let simd = SimdMatcher::new(&p.dfa, &vu)?
            .lookahead(1)
            .run_syms(slice)?;

        let ok = mc.accepted == want.accepted
            && mc.final_state == want.final_state
            && cloud.final_state == want.final_state
            && simd.final_state == want_slice.final_state;
        t.row(vec![
            p.name.clone(),
            p.q().to_string(),
            la.i_max.to_string(),
            want.accepted.to_string(),
            format!("{seq_ms:.1}"),
            format!("{mc_speedup:.1}x"),
            format!("{:.1}x", cloud.speedup()),
            format!("{:.2}x", simd.instr_speedup()),
            if ok { "OK".into() } else { "MISMATCH".into() },
        ]);
        assert!(ok, "layer disagreement on {}", p.name);
    }
    t.print();
    println!("all layers agree with sequential semantics — \
              failure-freedom holds end-to-end");
    Ok(())
}
