//! Cloud deployment simulation — the paper's EC2 scenario (§5.2, §6.2):
//! heterogeneous clusters, offline profiling + weighted partitioning, and
//! the 2-tier hierarchical merge against its alternatives.
//!
//!     cargo run --release --example cloud_sim

use specdfa::cluster::{CloudMatcher, ClusterSpec};
use specdfa::compile_prosite;
use specdfa::engine::{select, AutoThresholds, DfaProps};
use specdfa::speculative::merge::MergeStrategy;
use specdfa::util::bench::Table;
use specdfa::workload::InputGen;

fn main() -> anyhow::Result<()> {
    let dfa = compile_prosite("C-x(2,4)-C-x(3)-[LIVMFYWC]-x(4)-H-x(3,5)-H.")?;
    println!("zinc-finger DFA: |Q|={}", dfa.num_states);
    let syms = InputGen::new(3).uniform_syms(&dfa, 8_000_000);

    // 1. Merge strategy shoot-out on a 20-node cluster (Fig. 9 / §5.2).
    let mut t = Table::new(
        "merge strategies, 20 cc2.8xlarge nodes (300 cores), 8M symbols",
        &["strategy", "makespan ms", "comm %", "speedup"],
    );
    for (name, strat) in [
        ("sequential (Eq. 8)", MergeStrategy::Sequential),
        ("binary tree (Eq. 9)", MergeStrategy::BinaryTree),
        ("hierarchical 2-tier (Fig. 9)",
         MergeStrategy::Hierarchical { cores_per_node: 15 }),
    ] {
        let out = CloudMatcher::new(&dfa, ClusterSpec::homogeneous(20))
            .lookahead(4)
            .merge_strategy(strat)
            .seed(17)
            .run_syms(&syms);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", out.makespan_us / 1e3),
            format!("{:.2}", out.comm_ratio() * 100.0),
            format!("{:.1}x", out.speedup()),
        ]);
    }
    t.print();

    // 2. Load balancing across fast/slow instance mixes (Table 3).
    let mut t = Table::new(
        "inhomogeneous clusters: capacity-weighted partitioning (Eq. 1)",
        &["fast", "slow", "balance CV", "speedup"],
    );
    for (fast, slow) in [(0, 5), (2, 3), (4, 1), (5, 0)] {
        let out = CloudMatcher::new(&dfa, ClusterSpec::fast_slow(fast, slow))
            .lookahead(1)
            .seed(19)
            .run_syms(&syms);
        t.row(vec![
            fast.to_string(),
            slow.to_string(),
            format!("{:.4}", out.balance_cv()),
            format!("{:.1}x", out.speedup()),
        ]);
    }
    t.print();

    // 3. The leave-one-core-idle rule vs hypervisor preemption (§5.2).
    let mut t = Table::new(
        "hypervisor preemption: allocate 15/16 vs 16/16 cores per node",
        &["allocation", "makespan ms", "speedup"],
    );
    for (name, spec) in [
        ("15 of 16 cores (paper's rule)", ClusterSpec::homogeneous(8)),
        ("all 16 cores (preemption risk)",
         ClusterSpec::homogeneous(8).allocate_all_cores()),
    ] {
        let out = CloudMatcher::new(&dfa, spec)
            .lookahead(4)
            .seed(23)
            .run_syms(&syms);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", out.makespan_us / 1e3),
            format!("{:.1}x", out.speedup()),
        ]);
    }
    t.print();

    // 4. Where the unified facade's Engine::Auto places this workload:
    //    8M symbols on a zinc-finger DFA is cluster territory.
    let props = DfaProps::analyze(&dfa, 4);
    let sel = select(&props, syms.len(), &AutoThresholds::default());
    println!("\nEngine::Auto would serve this request via {sel}");
    Ok(())
}
