//! Cloud deployment simulation — the paper's EC2 scenario (§5.2, §6.2)
//! grown into **hierarchical cross-substrate sharding**: one corpus-scale
//! input split across cluster nodes *and*, inside every node, across that
//! node's cores, with Eq. (1) capacity weights at both levels.
//!
//!     cargo run --release --example cloud_sim

use specdfa::cluster::{CloudMatcher, ClusterSpec};
use specdfa::compile_prosite;
use specdfa::engine::shard::ShardPlan;
use specdfa::engine::{select, AutoThresholds, DfaProps};
use specdfa::speculative::merge::MergeStrategy;
use specdfa::speculative::profile::profile_workers;
use specdfa::util::bench::Table;
use specdfa::workload::InputGen;
use specdfa::SequentialMatcher;

fn main() -> anyhow::Result<()> {
    let dfa = compile_prosite("C-x(2,4)-C-x(3)-[LIVMFYWC]-x(4)-H-x(3,5)-H.")?;
    println!("zinc-finger DFA: |Q|={}", dfa.num_states);
    let syms = InputGen::new(3).uniform_syms(&dfa, 8_000_000);

    // 1. Merge strategy shoot-out on a 20-node cluster (Fig. 9 / §5.2).
    let mut t = Table::new(
        "merge strategies, 20 cc2.8xlarge nodes (300 cores), 8M symbols",
        &["strategy", "makespan ms", "comm %", "speedup"],
    );
    for (name, strat) in [
        ("sequential (Eq. 8)", MergeStrategy::Sequential),
        ("binary tree (Eq. 9)", MergeStrategy::BinaryTree),
        ("hierarchical 2-tier (Fig. 9)",
         MergeStrategy::Hierarchical { cores_per_node: 15 }),
    ] {
        let out = CloudMatcher::new(&dfa, ClusterSpec::homogeneous(20))
            .lookahead(4)
            .merge_strategy(strat)
            .seed(17)
            .run_syms(&syms);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", out.makespan_us / 1e3),
            format!("{:.2}", out.comm_ratio() * 100.0),
            format!("{:.1}x", out.speedup()),
        ]);
    }
    t.print();

    // 2. Hierarchical sharding (engine::shard): the same corpus is split
    //    across nodes AND across each node's workers — a two-level
    //    Eq. (1) partition, merged bottom-up.  Here with a deliberately
    //    inhomogeneous cluster: a fast 4-worker node, a mixed node with
    //    one degraded worker, and a slow 2-worker node.
    let nodes = vec![
        vec![2.0, 2.0, 2.0, 2.0], // fast node
        vec![1.0, 1.0, 0.2, 1.0], // one preempted/slow worker
        vec![0.5, 0.5],           // small slow node
    ];
    let plan = ShardPlan::new(&dfa)
        .node_capacities(nodes.clone())
        .lookahead(4);
    let out = plan.run_syms(&syms);
    let seq = SequentialMatcher::new(&dfa).run_syms(&syms);
    assert_eq!(out.final_state, seq.final_state, "failure-freedom");
    let mut t = Table::new(
        "hierarchical shard: 3 inhomogeneous nodes, per-worker Eq. (1)",
        &["node", "workers", "capacity", "chunk syms", "share %",
          "matched syms"],
    );
    let per_node = out.per_node_syms();
    let layout = plan.layout(syms.len());
    for (i, caps) in nodes.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            caps.len().to_string(),
            format!("{:.1}", caps.iter().sum::<f64>()),
            layout.node_chunks[i].len().to_string(),
            format!(
                "{:.1}",
                100.0 * layout.node_chunks[i].len() as f64
                    / syms.len() as f64
            ),
            per_node[i].to_string(),
        ]);
    }
    t.print();
    println!(
        "shard makespan {} syms vs sequential {} -> {:.2}x work-model \
         speedup ({} overhead syms, merge: {} composes, {} inter-node \
         msgs)\n",
        out.makespan_syms(),
        syms.len(),
        syms.len() as f64 / out.makespan_syms().max(1) as f64,
        out.speculative_overhead_syms(syms.len()),
        out.merge_stats.compose_ops,
        out.merge_stats.inter_node_msgs,
    );

    // 3. A *measured* per-worker capacity vector (§4.1 profiling, one
    //    rate per concurrent worker thread of this host) driving the
    //    intra-node partition — the serving path's configuration.
    let cv = profile_workers(4, 3, 1 << 16);
    println!(
        "measured per-worker capacity vector: {:?} sym/us (skew {:.3})",
        cv.rates.iter().map(|r| r.round()).collect::<Vec<_>>(),
        cv.skew()
    );
    let measured = ShardPlan::new(&dfa)
        .capacity_vector(4, &cv)
        .lookahead(4)
        .run_syms(&syms);
    assert_eq!(measured.final_state, seq.final_state);
    println!(
        "4 nodes x measured vector: makespan {} syms, {:.2}x work-model \
         speedup\n",
        measured.makespan_syms(),
        syms.len() as f64 / measured.makespan_syms().max(1) as f64
    );

    // 4. Load balancing across fast/slow instance mixes (Table 3).
    let mut t = Table::new(
        "inhomogeneous clusters: capacity-weighted partitioning (Eq. 1)",
        &["fast", "slow", "balance CV", "speedup"],
    );
    for (fast, slow) in [(0, 5), (2, 3), (4, 1), (5, 0)] {
        let out = CloudMatcher::new(&dfa, ClusterSpec::fast_slow(fast, slow))
            .lookahead(1)
            .seed(19)
            .run_syms(&syms);
        t.row(vec![
            fast.to_string(),
            slow.to_string(),
            format!("{:.4}", out.balance_cv()),
            format!("{:.1}x", out.speedup()),
        ]);
    }
    t.print();

    // 5. The leave-one-core-idle rule vs hypervisor preemption (§5.2).
    let mut t = Table::new(
        "hypervisor preemption: allocate 15/16 vs 16/16 cores per node",
        &["allocation", "makespan ms", "speedup"],
    );
    for (name, spec) in [
        ("15 of 16 cores (paper's rule)", ClusterSpec::homogeneous(8)),
        ("all 16 cores (preemption risk)",
         ClusterSpec::homogeneous(8).allocate_all_cores()),
    ] {
        let out = CloudMatcher::new(&dfa, spec)
            .lookahead(4)
            .seed(23)
            .run_syms(&syms);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", out.makespan_us / 1e3),
            format!("{:.1}x", out.speedup()),
        ]);
    }
    t.print();

    // 6. Where the unified facade's Engine::Auto places this workload: at
    //    8M symbols it is cloud territory; past AutoThresholds::shard_min_n
    //    the two-level shard engine takes over.
    let props = DfaProps::analyze(&dfa, 4);
    let thresholds = AutoThresholds::default();
    for n in [syms.len(), thresholds.shard_min_n] {
        let sel = select(&props, n, &thresholds);
        println!("Engine::Auto at n={n}: {sel}");
    }
    Ok(())
}
