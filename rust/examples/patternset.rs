//! Multi-pattern matching with `engine::patternset`: compile k patterns
//! into one `CompiledSetMatcher` — an Aho–Corasick literal prefilter, a
//! fused product DFA with per-pattern accept bitmasks, and a
//! budget-bounded spill tier — then answer every pattern's membership
//! query in one coordinated input pass.
//!
//!     cargo run --release --example patternset

use specdfa::engine::{
    CompiledMatcher, CompiledSetMatcher, Engine, ExecPolicy, Matcher,
    Pattern, PatternSet, SetConfig, SetTier,
};
use specdfa::workload::InputGen;

fn main() -> anyhow::Result<()> {
    // 1. A set of route patterns a log-scanning service watches for.
    //    Duplicates are deduped at compile time (one compile, one shared
    //    verdict slot); each pattern's required literal feeds the
    //    prefilter tier.
    let sources = [
        r"GET /api/[a-z]+ HTTP/1\.[01]",
        r"POST /login HTTP/1\.[01]",
        r"(error|panic): [a-z ]+",
        r"GET /api/[a-z]+ HTTP/1\.[01]", // duplicate of slot 0
        r"timeout after [0-9]+ms",
    ];
    let set = PatternSet::from_patterns(
        sources.iter().map(|s| Pattern::Regex(s.to_string())).collect(),
    );
    let csm = CompiledSetMatcher::compile(&set, SetConfig::default())?;
    println!("{}\n", csm.describe());
    assert_eq!(csm.unique_patterns(), 4, "the duplicate shares a compile");

    // 2. One pass answers all five slots.  The input contains two of
    //    the patterns; the prefilter clears the rest without ever
    //    touching the product DFA with them.
    let mut gen = InputGen::new(7);
    let mut log = gen.ascii_text(1 << 20);
    let hit_a = b"GET /api/users HTTP/1.1";
    log[4096..4096 + hit_a.len()].copy_from_slice(hit_a);
    let hit_b = b"error: disk full";
    log[65536..65536 + hit_b.len()].copy_from_slice(hit_b);
    let out = csm.run_bytes(&log)?;
    for (slot, (o, tier)) in
        out.outcomes.iter().zip(out.tiers.iter()).enumerate()
    {
        let tier = match tier {
            SetTier::PrefilterCleared => "prefilter",
            SetTier::Fused => "fused",
            SetTier::Spilled => "spilled",
        };
        println!(
            "slot {slot}: accepted={:<5} [{tier:>9}] {}",
            o.accepted, sources[slot]
        );
    }
    println!(
        "\none pass over {} B: {} fused pattern(s), {} spilled, \
         {} cleared by the prefilter",
        log.len(),
        csm.fused_patterns(),
        csm.spilled_patterns(),
        out.prefilter_cleared
    );
    assert!(out.accepted()[0] && out.accepted()[2]);
    assert_eq!(out.accepted()[0], out.accepted()[3], "duplicate slots agree");

    // 3. Failure-freedom, set edition: every slot equals an independent
    //    sequential run of that pattern alone.
    for (slot, src) in sources.iter().enumerate() {
        let solo = CompiledMatcher::compile(
            &Pattern::Regex(src.to_string()),
            Engine::Sequential,
            ExecPolicy::default(),
        )?
        .run_bytes(&log)?;
        assert_eq!(out.outcomes[slot].accepted, solo.accepted, "slot {slot}");
    }
    println!("verified: every slot equals its independent sequential run");

    // 4. The state budget caps product-DFA growth.  A tiny budget
    //    spills every pattern back to per-pattern matching — slower,
    //    never wrong.
    let tiny = CompiledSetMatcher::compile(
        &set,
        SetConfig { state_budget: 1, ..SetConfig::default() },
    )?;
    assert_eq!(tiny.fused_patterns(), 0);
    let tiny_out = tiny.run_bytes(&log)?;
    assert_eq!(tiny_out.accepted(), out.accepted(), "spill tier agrees");
    println!(
        "budget 1: all {} unique pattern(s) spilled, verdicts unchanged",
        tiny.unique_patterns()
    );

    // 5. The speculative multicore kernel drives the fused DFA the same
    //    way it drives a single-pattern one: one parallel traversal,
    //    k verdicts.
    let spec = CompiledSetMatcher::compile(
        &set,
        SetConfig {
            engine: Engine::speculative(),
            policy: ExecPolicy {
                processors: 8,
                lookahead: 2,
                ..ExecPolicy::default()
            },
            ..SetConfig::default()
        },
    )?;
    let spec_out = spec.run_bytes(&log)?;
    assert_eq!(spec_out.accepted(), out.accepted());
    println!(
        "speculative fused pass (8 workers): verdicts unchanged, wall \
         {:.1} ms",
        spec_out.wall_s * 1e3
    );
    Ok(())
}
