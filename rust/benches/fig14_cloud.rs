//! Regenerates Fig. 14 (EC2 speedups + communication ratio) of the paper. Run: cargo bench --bench fig14_cloud
fn main() {
    for t in specdfa::experiments::run("fig14").expect("known experiment") {
        t.print();
    }
}
