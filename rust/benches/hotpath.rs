//! Hot-path micro-benchmarks — the §Perf profiling harness.
//!
//! Measures, per layer:
//!   L3 scalar loop     ns/symbol of the Listing-1 flat-table loop
//!                      (bytes vs premapped symbols, |Q| sweep for cache
//!                      behaviour)
//!   L3 lookahead       I_max,r analysis cost (BFS vs Algorithm 4)
//!   L3 merge           L-vector compose / lookup throughput
//!   L1/L2 via PJRT     per-call overhead + per-symbol throughput of the
//!                      compiled lane_match executable
//!
//! Run: cargo bench --bench hotpath   (or `make perf`)

use std::time::Instant;

use specdfa::automata::FlatDfa;
use specdfa::regex::compile::compile_search;
use specdfa::runtime::pjrt::{pad_table, VectorUnit};
use specdfa::speculative::lookahead::{i_max_r_naive, Lookahead};
use specdfa::speculative::lvector::LVector;
use specdfa::util::bench::{time_median, Table};
use specdfa::util::rng::Rng;
use specdfa::workload::{pcre_like, InputGen};

fn main() {
    scalar_loop();
    lookahead_cost();
    merge_cost();
    pjrt_cost();
}

fn scalar_loop() {
    let mut t = Table::new(
        "L3 scalar hot loop (Listing 1)",
        &["|Q|", "width", "ns/sym (bytes)", "ns/sym (premapped)",
          "ns/state-sym (x8)", "MB/s (bytes)"],
    );
    let mut rng = Rng::new(0x607);
    for target_q in [8usize, 64, 256, 512, 1024] {
        let p = pcre_like::generate_sized(&mut rng, target_q);
        let flat = FlatDfa::from_dfa(&p.dfa);
        let n = 4_000_000;
        let mut gen = InputGen::new(1);
        let bytes = gen.ascii_text(n);
        let syms = p.dfa.map_input(&bytes);
        let tb = time_median(1, 5, || flat.run_bytes(flat.start_off, &bytes));
        let ts = time_median(1, 5, || flat.run_syms(flat.start_off, &syms));
        let t8 = time_median(1, 5, || {
            flat.run_syms_x8([flat.start_off; 8], &syms)
        });
        t.row(vec![
            p.dfa.num_states.to_string(),
            flat.width().name().to_string(),
            format!("{:.3}", tb * 1e9 / n as f64),
            format!("{:.3}", ts * 1e9 / n as f64),
            format!("{:.3}", t8 * 1e9 / (8 * n) as f64),
            format!("{:.0}", n as f64 / tb / 1e6),
        ]);
    }
    t.print();
}

fn lookahead_cost() {
    let mut t = Table::new(
        "L3 lookahead analysis cost",
        &["|Q|", "bfs r=4 µs", "alg4 r=2 µs"],
    );
    let mut rng = Rng::new(0x607_2);
    for target_q in [32usize, 128, 512] {
        let p = pcre_like::generate_sized(&mut rng, target_q);
        let t_bfs = time_median(1, 3, || Lookahead::analyze(&p.dfa, 4).i_max);
        let t_naive = time_median(1, 3, || i_max_r_naive(&p.dfa, 2));
        t.row(vec![
            p.dfa.num_states.to_string(),
            format!("{:.1}", t_bfs * 1e6),
            format!("{:.1}", t_naive * 1e6),
        ]);
    }
    t.print();
}

fn merge_cost() {
    let mut t = Table::new(
        "L3 merge primitives",
        &["|Q|", "compose ns", "lookup ns"],
    );
    let mut rng = Rng::new(0x607_3);
    for q in [16usize, 256, 1536] {
        let mk = |rng: &mut Rng| {
            let mut lv = LVector::identity(q);
            for i in 0..q {
                lv.set(i as u32, rng.below(q as u64) as u32);
            }
            lv
        };
        let a = mk(&mut rng);
        let b = mk(&mut rng);
        let tc = time_median(10, 50, || a.compose(&b));
        let tl = time_median(10, 50, || {
            let mut s = 0u32;
            for _ in 0..1000 {
                s = a.get(s % q as u32);
            }
            s
        });
        t.row(vec![
            q.to_string(),
            format!("{:.0}", tc * 1e9),
            format!("{:.2}", tl * 1e9 / 1000.0),
        ]);
    }
    t.print();
}

fn pjrt_cost() {
    let vu = match VectorUnit::load(VectorUnit::default_dir(), "lane8_small")
    {
        Ok(v) => std::sync::Arc::new(v),
        Err(e) => {
            println!("PJRT bench skipped: {e:#}");
            return;
        }
    };
    let dfa = compile_search("(ab|cd)+").unwrap();
    let table = pad_table(
        &dfa.table,
        dfa.num_states as usize,
        dfa.num_symbols as usize,
        &vu.spec,
    )
    .unwrap();
    let mut gen = InputGen::new(2);
    let syms = gen.uniform_syms(&dfa, vu.spec.n);
    let inp: Vec<i32> = syms.iter().map(|&s| s as i32).collect();
    let starts = vec![0i32; vu.spec.lanes];
    let init = vec![0i32; vu.spec.lanes];
    // device-resident table (set once; §Perf optimization)
    vu.set_table(&table).unwrap();

    let mut t = Table::new(
        "L1/L2 PJRT lane_match executable (lane8_small)",
        &["lens", "µs/call", "ns/lane-sym"],
    );
    for frac in [0usize, 1, 2] {
        let len = match frac {
            0 => 0,
            1 => vu.spec.t / 2,
            _ => vu.spec.t,
        };
        let lens = vec![len as i32; vu.spec.lanes];
        let tc = time_median(3, 15, || {
            vu.lane_match(&[], &inp, &starts, &lens, &init).unwrap()
        });
        let lane_syms = (len * vu.spec.lanes) as f64;
        t.row(vec![
            len.to_string(),
            format!("{:.1}", tc * 1e6),
            if lane_syms > 0.0 {
                format!("{:.1}", tc * 1e9 / lane_syms)
            } else {
                "-".into()
            },
        ]);
    }
    t.print();

    // end-to-end call-chain throughput on a long chunk
    let dfa2 = compile_search("needle").unwrap();
    let m = specdfa::runtime::simd::SimdMatcher::new(&dfa2, &vu)
        .unwrap()
        .lookahead(1);
    let syms2 = InputGen::new(3).uniform_syms(&dfa2, 1 << 16);
    let t0 = Instant::now();
    let out = m.run_syms(&syms2).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "SimdMatcher 64Ki syms: {:.1} ms wall, {} pjrt calls, \
         chunk-speedup {:.2}x, instr-speedup {:.2}x\n",
        dt * 1e3,
        out.pjrt_calls,
        out.chunk_speedup(),
        out.instr_speedup()
    );
}
