//! Regenerates Fig. 12 (vs ScanProsite and grep) of the paper. Run: cargo bench --bench fig12_scanprosite
fn main() {
    for t in specdfa::experiments::run("fig12").expect("known experiment") {
        t.print();
    }
}
