//! Regenerates Fig. 17 (I_max,r computation overhead) of the paper. Run: cargo bench --bench fig17_overhead
fn main() {
    for t in specdfa::experiments::run("fig17").expect("known experiment") {
        t.print();
    }
}
