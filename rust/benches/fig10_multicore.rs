//! Regenerates Fig. 10 (MTL multicore speedups + I_max gains) of the paper. Run: cargo bench --bench fig10_multicore
fn main() {
    for t in specdfa::experiments::run("fig10").expect("known experiment") {
        t.print();
    }
}
