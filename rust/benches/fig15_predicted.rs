//! Regenerates Fig. 15 (observed vs Eq. 15 prediction) of the paper. Run: cargo bench --bench fig15_predicted
fn main() {
    for t in specdfa::experiments::run("fig15").expect("known experiment") {
        t.print();
    }
}
