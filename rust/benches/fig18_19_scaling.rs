//! Regenerates Fig. 18 (shared-memory input-size scaling) and Fig. 19
//! (cloud input-size scaling). Run: cargo bench --bench fig18_19_scaling
//! Set SPECDFA_BIG=1 for the 1 GB rows.
fn main() {
    for name in ["fig18", "fig19"] {
        for t in specdfa::experiments::run(name).expect("known experiment") {
            t.print();
        }
    }
}
