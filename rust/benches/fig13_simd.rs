//! Regenerates Fig. 13 (8-lane vectorized matching) of the paper. Run: cargo bench --bench fig13_simd
fn main() {
    for t in specdfa::experiments::run("fig13").expect("known experiment") {
        t.print();
    }
}
