//! Regenerates Fig. 16 and Table 4 (initial-state reduction rates).
//! Run: cargo bench --bench fig16_table4_lookahead
fn main() {
    for name in ["fig16", "table4"] {
        for t in specdfa::experiments::run(name).expect("known experiment") {
            t.print();
        }
    }
}
