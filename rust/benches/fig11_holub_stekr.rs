//! Regenerates Fig. 11 (Holub-Stekr comparator speed-downs) of the paper. Run: cargo bench --bench fig11_holub_stekr
fn main() {
    for t in specdfa::experiments::run("fig11").expect("known experiment") {
        t.print();
    }
}
