//! Regenerates Table 3 (load balancing on inhomogeneous clusters) of the paper. Run: cargo bench --bench table3_loadbalance
fn main() {
    for t in specdfa::experiments::run("table3").expect("known experiment") {
        t.print();
    }
}
