//! Quickstart: compile a regex, run the speculative parallel membership
//! test, and verify failure-freedom against the sequential matcher.
//!
//!     cargo run --release --example quickstart

use specdfa::speculative::lookahead::Lookahead;
use specdfa::speculative::matcher::MatchPlan;
use specdfa::workload::InputGen;
use specdfa::{compile_search, SequentialMatcher};

fn main() -> anyhow::Result<()> {
    // 1. Pattern -> minimal DFA (Thompson NFA -> subset construction ->
    //    Hopcroft), with "input contains a match" semantics.
    let dfa = compile_search(r"GET /[a-z0-9/]{1,16} HTTP/1\.[01]")?;
    println!("compiled: |Q|={} |Sigma|={}", dfa.num_states, dfa.num_symbols);

    // 2. Structural analysis: how speculation-friendly is this DFA?
    let la = Lookahead::analyze(&dfa, 4);
    println!(
        "I_max by lookahead depth: {:?}  (gamma = {:.3})",
        la.i_max_by_r,
        la.gamma(&dfa)
    );

    // 3. A 4 MB synthetic log with a planted request line.
    let mut gen = InputGen::new(42);
    let mut input = gen.ascii_text(4 << 20);
    gen.plant(&mut input, b"GET /index/html HTTP/1.1", 5);

    // 4. Sequential yardstick (Listing 1).
    let seq = SequentialMatcher::new(&dfa).run_bytes(&input);
    println!("sequential: accepted={}", seq.accepted);

    // 5. Speculative parallel run: 8 processors, 4-symbol reverse
    //    lookahead, balanced partitioning.
    let plan = MatchPlan::new(&dfa).processors(8).lookahead(4);
    let out = plan.run(&input);
    println!(
        "parallel:   accepted={} (final state {})",
        out.accepted, out.final_state
    );
    println!(
        "work: makespan {} of {} symbols -> model speedup {:.2}x \
         (Eq. 18 bound: {:.2}x)",
        out.makespan_syms(),
        input.len(),
        input.len() as f64 / out.makespan_syms() as f64,
        1.0 + 7.0 / out.m as f64,
    );

    // 6. Failure-freedom: the results are identical by construction.
    assert_eq!(out.accepted, seq.accepted);
    assert_eq!(out.final_state, seq.final_state);
    println!("failure-freedom verified: parallel == sequential");
    Ok(())
}
