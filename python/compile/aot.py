"""AOT entry point: lower the L2 model to HLO *text* artifacts.

HLO text (NOT lowered.compiler_ir("hlo") protos / .serialize()) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which the rust `xla` crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`).  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Usage (from repo root):
    python python/compile/aot.py --out artifacts

Produces artifacts/<variant>.hlo.txt for every VariantSpec in model.py,
artifacts/compose.hlo.txt for the Eq. 9 merge kernel, and
artifacts/manifest.json describing the static shapes so the rust runtime can
pick variants and pad accordingly.  Deterministic: same inputs -> same text.
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(spec: model.VariantSpec) -> str:
    lowered = jax.jit(spec.bind()).lower(*spec.abstract_args())
    return to_hlo_text(lowered)


def lower_compose(qp: int) -> str:
    arg = jax.ShapeDtypeStruct((qp,), jnp.int32)
    lowered = jax.jit(model.compose).lower(arg, arg)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="artifacts", help="output directory")
    ap.add_argument("--only", default=None,
                    help="build a single named variant (for tests)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "modules": {}}
    for spec in model.VARIANTS:
        if args.only and spec.name != args.only:
            continue
        text = lower_variant(spec)
        path = os.path.join(args.out, f"{spec.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["modules"][spec.name] = spec.manifest_entry()
        print(f"wrote {path} ({len(text)} chars)")

    if not args.only:
        text = lower_compose(model.COMPOSE_QP)
        path = os.path.join(args.out, "compose.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["modules"]["compose"] = {"kind": "compose",
                                          "qp": model.COMPOSE_QP}
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {mpath}")

    # TSV manifest for the rust runtime (built offline, without a JSON dep):
    # lane_match rows: name kind lanes q s t n block_t
    # compose row:     compose compose qp 0 0 0 0 0
    tpath = os.path.join(args.out, "manifest.tsv")
    with open(tpath, "w") as f:
        for name, e in sorted(manifest["modules"].items()):
            if e["kind"] == "lane_match":
                f.write(f"{name}\tlane_match\t{e['lanes']}\t{e['q']}\t"
                        f"{e['s']}\t{e['t']}\t{e['n']}\t{e['block_t']}\n")
            else:
                f.write(f"{name}\tcompose\t{e['qp']}\t0\t0\t0\t0\t0\n")
    print(f"wrote {tpath}")


if __name__ == "__main__":
    main()
