"""Layer-2 JAX model: the speculative lane-matching compute graph.

This is the whole computation the paper's AVX2 inner loop (Listing 2)
performs per SIMD register, expressed in JAX and lowered ONCE to HLO text by
aot.py.  Python never runs at match time: the rust coordinator loads the
compiled artifact via PJRT and feeds it the flattened transition table
(SBase), the symbol-mapped input window (IBase), and per-lane descriptors.

Graph structure per artifact variant (all shapes static):

    lane_match(table_flat, inp, starts, lens, init) -> (final_states,)

      table_flat : i32[Q*S]   flattened SBase (Fig. 8c); rust re-strides its
                              DFA to the artifact's (Q, S) padding
      inp        : i32[N]     IBase window: symbol-mapped input (Fig. 8d)
      starts     : i32[L]     per-lane start offset into `inp`
      lens       : i32[L]     per-lane number of symbols to consume (<= T)
      init       : i32[L]     per-lane initial DFA state
      final      : i32[L]     delta*(init[l], inp[starts[l] : starts[l]+lens[l]])

The per-lane windowing gather (the `_mm256_i32gather_epi32(IBase, InpIdx)`
half of Listing 2) happens here in L2 as a vectorized take; the data-
dependent SBase gather — the irreducible, serially-dependent half — lives in
the L1 Pallas kernel so both lower into the same HLO module.

One artifact call advances every lane by at most T symbols; the rust side
carries `final -> init` across calls for longer chunks, exactly like the
paper's loop carries `States` across iterations.
"""

from functools import partial

import jax
import jax.numpy as jnp

from compile.kernels.dfa_match import lane_dfa_match, DEFAULT_BLOCK_T
from compile.kernels.merge import compose_lvectors

__all__ = ["lane_match", "compose", "VariantSpec", "VARIANTS"]


def lane_match(table_flat, inp, starts, lens, init, *, q, s, t,
               block_t=DEFAULT_BLOCK_T):
    """Advance `L` speculative lanes by up to `t` symbols each.

    Static config: q, s (table padding), t (max symbols per call), block_t
    (kernel time tile).  Returns a 1-tuple (final_states,) so the lowered
    module is a tuple — the rust loader unwraps with to_tuple1().
    """
    table = table_flat.reshape(q, s)
    lanes = starts.shape[0]
    n = inp.shape[0]
    # Per-lane window gather (IBase gather of Listing 2).  Out-of-range
    # positions are clipped; the kernel masks them out via `lens`.
    idx = starts[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    idx = jnp.clip(idx, 0, n - 1)
    syms = jnp.take(inp, idx)
    lens = jnp.minimum(lens, jnp.int32(t))
    final = lane_dfa_match(table, syms, lens, init, block_t=block_t)
    return (final,)


def compose(la, lb):
    """Eq. (9) L-vector composition as a lowered module: out[q]=lb[la[q]]."""
    return (compose_lvectors(la, lb),)


class VariantSpec:
    """Static-shape configuration of one AOT artifact."""

    def __init__(self, name, *, lanes, q, s, t, n, block_t=DEFAULT_BLOCK_T):
        if t % block_t != 0:
            raise ValueError(f"{name}: t={t} not a multiple of block_t={block_t}")
        self.name = name
        self.lanes = lanes
        self.q = q
        self.s = s
        self.t = t
        self.n = n
        self.block_t = block_t

    def abstract_args(self):
        i32 = jnp.int32
        return (
            jax.ShapeDtypeStruct((self.q * self.s,), i32),  # table_flat
            jax.ShapeDtypeStruct((self.n,), i32),           # inp
            jax.ShapeDtypeStruct((self.lanes,), i32),       # starts
            jax.ShapeDtypeStruct((self.lanes,), i32),       # lens
            jax.ShapeDtypeStruct((self.lanes,), i32),       # init
        )

    def bind(self):
        return partial(lane_match, q=self.q, s=self.s, t=self.t,
                       block_t=self.block_t)

    def manifest_entry(self):
        return {
            "kind": "lane_match",
            "lanes": self.lanes, "q": self.q, "s": self.s,
            "t": self.t, "n": self.n, "block_t": self.block_t,
        }


# The artifact family built by `make artifacts`.
#
#  * lane8_main — the production variant: 8 lanes (AVX2 width), table padded
#    to 1536 states x 64 symbols (384 KiB; covers the largest PROSITE DFA,
#    1288 states, and any symbol-mapped dense alphabet we generate), 64 Ki
#    IBase window, 8 Ki symbols advanced per call.
#  * lane32_wide — 32 lanes for deep speculation (many initial states) and
#    multi-chunk batching.
#  * lane8_small — tiny variant: fast to compile/execute, used by tests and
#    the quickstart example.
VARIANTS = [
    VariantSpec("lane8_main", lanes=8, q=1536, s=64, t=8192, n=1 << 16),
    VariantSpec("lane32_wide", lanes=32, q=1536, s=64, t=4096, n=1 << 16),
    VariantSpec("lane8_small", lanes=8, q=64, s=16, t=512, n=4096,
                block_t=128),
]

# Padded L-vector width for the compose artifact (must cover q of the main
# variants).
COMPOSE_QP = 1536
