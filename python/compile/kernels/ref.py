"""Pure-jnp / pure-python correctness oracles for the Pallas DFA kernel.

These implement Algorithm 1 of the paper (sequential DFA matching) lifted
over a lane dimension, with none of the kernel's tiling tricks — the ground
truth the L1 kernel and L2 model are pinned to by pytest + hypothesis.
"""

import jax
import jax.numpy as jnp

__all__ = ["lane_dfa_match_ref", "lane_dfa_match_py", "compose_ref"]


def lane_dfa_match_ref(table, syms, lens, init):
    """Oracle for kernels.dfa_match.lane_dfa_match, as a jax.lax.scan.

    table: i32[Q, S]; syms: i32[L, T]; lens: i32[L]; init: i32[L].
    Returns i32[L] final states.
    """
    t = syms.shape[1]

    def step(state, xs):
        sym, i = xs
        nxt = table[state, sym]
        return jnp.where(i < lens, nxt, state), None

    final, _ = jax.lax.scan(step, init, (syms.T, jnp.arange(t)))
    return final


def lane_dfa_match_py(table, syms, lens, init):
    """Pure-python Algorithm 1 over lanes (no jax). Lists/ints in, list out."""
    lanes = len(init)
    out = []
    for l in range(lanes):
        state = int(init[l])
        for i in range(int(lens[l])):
            state = int(table[state][int(syms[l][i])])
        out.append(state)
    return out


def compose_ref(la, lb):
    """Eq. (9) L-vector composition oracle: out[j] = lb[la[j]]."""
    return jnp.asarray(lb)[jnp.asarray(la)]
