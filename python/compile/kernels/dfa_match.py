"""Layer-1 Pallas kernel: lane-parallel DFA stepping (the SBase gather loop).

This is the TPU re-thinking of the paper's AVX2 matching loop (Listing 2):

    InpSyms = _mm256_i32gather_epi32(IBase, InpIdx, 4);
    States  = _mm256_add_epi32(States, InpSyms);
    States  = _mm256_i32gather_epi32(SBase, States, 4);

The 8 AVX2 lanes are speculative (chunk x initial-state) matches running in
lockstep.  On TPU there is no scalar gather instruction either; the paper's
core insight — "DFA stepping is a pure gather, so the whole loop vectorizes
once a gather primitive exists" — maps to:

  * the transition table SBase lives resident in VMEM for the whole kernel
    (worst-case PROSITE DFA: 1536 states x 64 symbols x 4 B = 384 KiB,
    comfortably inside a TensorCore's ~16 MiB VMEM),
  * the per-step data-dependent indexed load `SBase[state, sym]` is a
    vectorized take over the lane dimension,
  * the input stream is tiled HBM->VMEM by the BlockSpec grid over time
    blocks (`block_t` symbols per grid step), the role threadblock/stream
    scheduling plays in the paper's CPU version.

The kernel MUST be run with interpret=True on this CPU image: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Under jit, interpret mode lowers to plain HLO (the fori_loop becomes an XLA
while loop), so the artifact produced from this kernel is a real compiled
executable on the rust side.

Correctness oracle: kernels/ref.py (pure jax.lax.scan / pure python).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lane_dfa_match", "DEFAULT_BLOCK_T"]

# Time-tile size: symbols consumed per grid step.  512 keeps the (lanes x
# block_t) int32 input tile at 16 KiB for 8 lanes — small against the
# VMEM-resident table, large enough to amortize grid-step overhead.
DEFAULT_BLOCK_T = 512


def _dfa_kernel(table_ref, syms_ref, lens_ref, init_ref, out_ref, *, block_t):
    """One grid step: advance every lane by `block_t` symbols.

    table_ref : i32[Q, S]      whole transition table, VMEM-resident
    syms_ref  : i32[L, block_t] this step's symbol tile (pre-gathered IBase)
    lens_ref  : i32[L]         per-lane total symbol count (masking)
    init_ref  : i32[L]         per-lane initial DFA state
    out_ref   : i32[L]         per-lane current state, carried across steps
    """
    # Whole-table VMEM residency: one load, reused for every step.
    table = table_ref[...]
    lens = lens_ref[...]
    t0 = pl.program_id(0) * block_t

    @pl.when(pl.program_id(0) == 0)
    def _seed():
        out_ref[...] = init_ref[...]

    def body(i, state):
        # Per-lane symbol at local step i (IBase gather analog).
        sym = syms_ref[:, i]
        # The SBase gather: vectorized indexed load over the lane dimension.
        nxt = table[state, sym]
        # Lanes past their chunk length hold their state (identity step);
        # this is how variable-length chunks ride a static-shape kernel.
        keep = (t0 + i) < lens
        return jnp.where(keep, nxt, state)

    out_ref[...] = jax.lax.fori_loop(0, block_t, body, out_ref[...])


def lane_dfa_match(table, syms, lens, init, *, block_t=DEFAULT_BLOCK_T,
                   interpret=True):
    """Run `lanes` speculative DFA matches in lockstep.

    Args:
      table: i32[Q, S] dense transition table (state, symbol) -> state.
      syms:  i32[lanes, T] per-lane symbol streams; T % block_t == 0.
      lens:  i32[lanes] symbols to actually consume per lane (<= T).
      init:  i32[lanes] initial state per lane.
      block_t: time-tile size (static).
      interpret: must stay True on CPU images (see module docstring).

    Returns:
      i32[lanes] final state per lane, i.e. delta*(init[l], syms[l,:lens[l]]).
    """
    lanes, t = syms.shape
    if t % block_t != 0:
        raise ValueError(f"T={t} must be a multiple of block_t={block_t}")
    grid = t // block_t
    kernel = partial(_dfa_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            # Whole table every step (index_map pins block 0).
            pl.BlockSpec(table.shape, lambda i: (0, 0)),
            # Stream the symbol matrix one time-tile per grid step.
            pl.BlockSpec((lanes, block_t), lambda i: (0, i)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((lanes,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((lanes,), jnp.int32),
        interpret=interpret,
    )(table, syms, lens, init)
