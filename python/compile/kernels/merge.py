"""Layer-1 Pallas kernel: L-vector composition (Eq. 9 of the paper).

Combining the mappings of two adjacent chunks is itself a gather:

    L_{i,j}[q] = L_j[ L_i[q] ]    for all q in Q.

The paper merges L-vectors sequentially on shared memory (Eq. 8) and
hierarchically on EC2 (Fig. 9); either way the primitive combining step is
this one-gather composition.  Exposing it as a kernel lets the rust
coordinator offload merge trees of padded L-vectors to the same PJRT
executable path used for matching.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["compose_lvectors"]


def _compose_kernel(la_ref, lb_ref, out_ref):
    la = la_ref[...]
    out_ref[...] = lb_ref[...][la]


def compose_lvectors(la, lb, *, interpret=True):
    """Compose two L-vectors: out[q] = lb[la[q]].  la, lb: i32[Qp]."""
    (qp,) = la.shape
    return pl.pallas_call(
        _compose_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((qp,), lambda i: (0,)),
            pl.BlockSpec((qp,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((qp,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((qp,), jnp.int32),
        interpret=interpret,
    )(la, lb)
