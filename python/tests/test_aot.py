"""AOT path: lowering determinism, manifest correctness, HLO-text shape.

These tests exercise the exact code `make artifacts` runs, against the small
variant (the big ones are covered by the Makefile build + rust integration
tests, which load and execute the real artifacts).
"""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def small_spec():
    return [s for s in model.VARIANTS if s.name == "lane8_small"][0]


def test_lower_small_variant_is_hlo_text():
    text = aot.lower_variant(small_spec())
    assert text.startswith("HloModule")
    # entry layout mentions all five parameters and the tuple result
    assert "entry_computation_layout" in text
    assert "s32[8]" in text  # lanes
    assert "s32[4096]" in text  # input window
    # while loop present: the fori_loop lowered into real control flow
    assert "while" in text


def test_lowering_deterministic():
    a = aot.lower_variant(small_spec())
    b = aot.lower_variant(small_spec())
    assert a == b


def test_lower_compose():
    text = aot.lower_compose(64)
    assert text.startswith("HloModule")
    assert "s32[64]" in text


def test_cli_writes_manifest(tmp_path):
    out = str(tmp_path / "arts")
    subprocess.run(
        [sys.executable, os.path.join(REPO, "python", "compile", "aot.py"),
         "--out", out, "--only", "lane8_small"],
        check=True, cwd=REPO,
    )
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    entry = manifest["modules"]["lane8_small"]
    assert entry == small_spec().manifest_entry()
    assert os.path.exists(os.path.join(out, "lane8_small.hlo.txt"))


def test_manifest_matches_variant_list():
    names = {s.name for s in model.VARIANTS}
    assert names == {"lane8_main", "lane32_wide", "lane8_small"}
