"""L1 correctness: Pallas DFA kernel vs the pure oracles.

Hypothesis sweeps DFA shapes, lane counts, tile sizes and data; every case
asserts exact (integer) equality between the Pallas kernel (interpret mode),
the jax.lax.scan oracle and the pure-python Algorithm 1.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.dfa_match import lane_dfa_match
from compile.kernels.merge import compose_lvectors
from compile.kernels.ref import (
    compose_ref,
    lane_dfa_match_py,
    lane_dfa_match_ref,
)


def run_all(table, syms, lens, init, block_t):
    k = np.asarray(
        lane_dfa_match(
            jnp.asarray(table), jnp.asarray(syms), jnp.asarray(lens),
            jnp.asarray(init), block_t=block_t,
        )
    )
    r = np.asarray(
        lane_dfa_match_ref(
            jnp.asarray(table), jnp.asarray(syms), jnp.asarray(lens),
            jnp.asarray(init),
        )
    )
    p = np.asarray(lane_dfa_match_py(table, syms, lens, init))
    return k, r, p


def rand_case(rng, q, s, lanes, t):
    table = rng.integers(0, q, size=(q, s)).astype(np.int32)
    syms = rng.integers(0, s, size=(lanes, t)).astype(np.int32)
    lens = rng.integers(0, t + 1, size=(lanes,)).astype(np.int32)
    init = rng.integers(0, q, size=(lanes,)).astype(np.int32)
    return table, syms, lens, init


# Fixed shape set so the jit cache is reused across hypothesis examples.
SHAPES = [
    # (q, s, lanes, t, block_t)
    (2, 2, 1, 64, 32),
    (5, 3, 4, 128, 64),
    (16, 8, 8, 256, 64),
    (64, 16, 8, 512, 128),
    (33, 7, 16, 192, 64),
]


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), shape=st.sampled_from(SHAPES))
def test_kernel_matches_oracles_random(seed, shape):
    q, s, lanes, t, block_t = shape
    rng = np.random.default_rng(seed)
    table, syms, lens, init = rand_case(rng, q, s, lanes, t)
    k, r, p = run_all(table, syms, lens, init, block_t)
    np.testing.assert_array_equal(k, r)
    np.testing.assert_array_equal(k, p)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_kernel_sink_state_absorbs(seed):
    """Once in the sink (error) state the DFA must stay there (paper §2.1)."""
    rng = np.random.default_rng(seed)
    q, s, lanes, t = 8, 4, 8, 128
    table = rng.integers(0, q, size=(q, s)).astype(np.int32)
    sink = q - 1
    table[sink, :] = sink
    syms = rng.integers(0, s, size=(lanes, t)).astype(np.int32)
    lens = np.full((lanes,), t, dtype=np.int32)
    init = np.full((lanes,), sink, dtype=np.int32)
    k, r, p = run_all(table, syms, lens, init, 64)
    assert (k == sink).all() and (r == sink).all() and (p == sink).all()


def test_kernel_zero_length_lanes_identity():
    """lens == 0 lanes must return their initial state untouched."""
    rng = np.random.default_rng(7)
    table, syms, _, init = rand_case(rng, 16, 8, 8, 256)
    lens = np.zeros((8,), dtype=np.int32)
    k, r, p = run_all(table, syms, lens, init, 64)
    np.testing.assert_array_equal(k, init)
    np.testing.assert_array_equal(r, init)
    np.testing.assert_array_equal(p, init)


def test_kernel_full_length_vs_truncated_prefix():
    """Matching lens=m must equal matching the m-prefix at full length."""
    rng = np.random.default_rng(11)
    q, s, lanes, t = 16, 8, 8, 256
    table, syms, _, init = rand_case(rng, q, s, lanes, t)
    m = 100
    lens = np.full((lanes,), m, dtype=np.int32)
    k1, _, _ = run_all(table, syms, lens, init, 64)
    syms2 = syms.copy()
    syms2[:, m:] = 0  # garbage beyond the mask must not matter
    k2, _, _ = run_all(table, syms2, lens, init, 64)
    np.testing.assert_array_equal(k1, k2)


def test_kernel_lanes_independent():
    """Each lane's result depends only on its own (syms, len, init)."""
    rng = np.random.default_rng(13)
    q, s, lanes, t = 16, 8, 8, 256
    table, syms, lens, init = rand_case(rng, q, s, lanes, t)
    full, _, _ = run_all(table, syms, lens, init, 64)
    for l in [0, 3, 7]:
        solo_syms = np.tile(syms[l], (lanes, 1))
        solo_lens = np.full((lanes,), lens[l], dtype=np.int32)
        solo_init = np.full((lanes,), init[l], dtype=np.int32)
        solo, _, _ = run_all(table, solo_syms, solo_lens, solo_init, 64)
        assert solo[0] == full[l]


@pytest.mark.parametrize("block_t", [32, 64, 128, 256])
def test_kernel_block_t_invariance(block_t):
    """The time-tile size is a scheduling knob only — results identical."""
    rng = np.random.default_rng(17)
    table, syms, lens, init = rand_case(rng, 32, 8, 8, 256)
    k, r, _ = run_all(table, syms, lens, init, block_t)
    np.testing.assert_array_equal(k, r)


def test_kernel_rejects_misaligned_block():
    rng = np.random.default_rng(19)
    table, syms, lens, init = rand_case(rng, 8, 4, 4, 100)
    with pytest.raises(ValueError):
        lane_dfa_match(
            jnp.asarray(table), jnp.asarray(syms), jnp.asarray(lens),
            jnp.asarray(init), block_t=64,
        )


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), qp=st.sampled_from([8, 64, 1536]))
def test_compose_matches_ref(seed, qp):
    rng = np.random.default_rng(seed)
    la = rng.integers(0, qp, size=(qp,)).astype(np.int32)
    lb = rng.integers(0, qp, size=(qp,)).astype(np.int32)
    out = np.asarray(compose_lvectors(jnp.asarray(la), jnp.asarray(lb)))
    ref = np.asarray(compose_ref(la, lb))
    np.testing.assert_array_equal(out, ref)
    np.testing.assert_array_equal(out, lb[la])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_compose_associative(seed):
    """Eq. 9 composition must be associative — the merge-tree invariant."""
    rng = np.random.default_rng(seed)
    qp = 64
    ls = [rng.integers(0, qp, size=(qp,)).astype(np.int32) for _ in range(3)]

    def comp(a, b):
        return np.asarray(compose_lvectors(jnp.asarray(a), jnp.asarray(b)))

    left = comp(comp(ls[0], ls[1]), ls[2])
    right = comp(ls[0], comp(ls[1], ls[2]))
    np.testing.assert_array_equal(left, right)


def test_compose_identity():
    qp = 64
    ident = np.arange(qp, dtype=np.int32)
    rng = np.random.default_rng(23)
    la = rng.integers(0, qp, size=(qp,)).astype(np.int32)
    out = np.asarray(compose_lvectors(jnp.asarray(la), jnp.asarray(ident)))
    np.testing.assert_array_equal(out, la)
    out = np.asarray(compose_lvectors(jnp.asarray(ident), jnp.asarray(la)))
    np.testing.assert_array_equal(out, la)
