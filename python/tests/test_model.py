"""L2 correctness: the lane_match model vs a from-scratch python oracle.

The model adds the windowing gather (starts/lens into a shared IBase input)
on top of the L1 kernel; the oracle here recomputes everything from the raw
arrays with plain python loops.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.model import lane_match, VariantSpec, VARIANTS


def oracle(table_flat, inp, starts, lens, init, q, s, t):
    """delta*(init[l], inp[starts[l] : starts[l]+min(lens[l],t)])."""
    out = []
    n = len(inp)
    for l in range(len(starts)):
        state = int(init[l])
        m = min(int(lens[l]), t)
        for i in range(m):
            pos = min(max(int(starts[l]) + i, 0), n - 1)
            sym = int(inp[pos])
            state = int(table_flat[state * s + sym])
        out.append(state)
    return np.array(out, dtype=np.int32)


SMALL = VariantSpec("unit_small", lanes=8, q=32, s=8, t=256, n=2048,
                    block_t=64)


def run_model(spec, table_flat, inp, starts, lens, init):
    fn = spec.bind()
    (out,) = fn(
        jnp.asarray(table_flat), jnp.asarray(inp), jnp.asarray(starts),
        jnp.asarray(lens), jnp.asarray(init),
    )
    return np.asarray(out)


def rand_model_case(rng, spec):
    table_flat = rng.integers(0, spec.q, size=(spec.q * spec.s,)).astype(np.int32)
    inp = rng.integers(0, spec.s, size=(spec.n,)).astype(np.int32)
    starts = rng.integers(0, spec.n, size=(spec.lanes,)).astype(np.int32)
    lens = rng.integers(0, spec.t + 1, size=(spec.lanes,)).astype(np.int32)
    init = rng.integers(0, spec.q, size=(spec.lanes,)).astype(np.int32)
    return table_flat, inp, starts, lens, init


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_model_matches_oracle_random(seed):
    rng = np.random.default_rng(seed)
    spec = SMALL
    table_flat, inp, starts, lens, init = rand_model_case(rng, spec)
    got = run_model(spec, table_flat, inp, starts, lens, init)
    want = oracle(table_flat, inp, starts, lens, init, spec.q, spec.s, spec.t)
    np.testing.assert_array_equal(got, want)


def test_model_chained_calls_equal_one_long_match():
    """Carrying final->init across calls must equal one sequential run.

    This is the contract the rust runtime relies on to advance chunks longer
    than the artifact's static T.
    """
    rng = np.random.default_rng(3)
    spec = SMALL
    table_flat = rng.integers(0, spec.q, size=(spec.q * spec.s,)).astype(np.int32)
    inp = rng.integers(0, spec.s, size=(spec.n,)).astype(np.int32)
    total = 700  # needs ceil(700/256) = 3 calls
    start0 = 100
    init = rng.integers(0, spec.q, size=(spec.lanes,)).astype(np.int32)

    # chained artifact calls
    state = init.copy()
    consumed = 0
    while consumed < total:
        step = min(spec.t, total - consumed)
        starts = np.full((spec.lanes,), start0 + consumed, dtype=np.int32)
        lens = np.full((spec.lanes,), step, dtype=np.int32)
        state = run_model(spec, table_flat, inp, starts, lens, state)
        consumed += step

    # one long python run
    want = []
    for l in range(spec.lanes):
        st_ = int(init[l])
        for i in range(total):
            st_ = int(table_flat[st_ * spec.s + int(inp[start0 + i])])
        want.append(st_)
    np.testing.assert_array_equal(state, np.array(want, dtype=np.int32))


def test_model_lanes_share_chunk_different_initials():
    """The speculative use-case: same window, 8 candidate initial states."""
    rng = np.random.default_rng(5)
    spec = SMALL
    table_flat = rng.integers(0, spec.q, size=(spec.q * spec.s,)).astype(np.int32)
    inp = rng.integers(0, spec.s, size=(spec.n,)).astype(np.int32)
    starts = np.full((spec.lanes,), 64, dtype=np.int32)
    lens = np.full((spec.lanes,), 200, dtype=np.int32)
    init = np.arange(spec.lanes, dtype=np.int32)
    got = run_model(spec, table_flat, inp, starts, lens, init)
    want = oracle(table_flat, inp, starts, lens, init, spec.q, spec.s, spec.t)
    np.testing.assert_array_equal(got, want)
    # The run is a true L-vector fragment: got[j] = delta*(q_j, chunk).


def test_variant_specs_are_consistent():
    for spec in VARIANTS:
        assert spec.t % spec.block_t == 0
        assert spec.q >= 2 and spec.s >= 2 and spec.lanes >= 1
        assert spec.n >= spec.t
        entry = spec.manifest_entry()
        assert entry["kind"] == "lane_match"
        assert entry["q"] * entry["s"] == spec.q * spec.s


def test_variant_table_fits_vmem_budget():
    """DESIGN §Hardware-Adaptation: table must stay VMEM-resident (<16 MiB)."""
    for spec in VARIANTS:
        table_bytes = spec.q * spec.s * 4
        tile_bytes = spec.lanes * spec.block_t * 4
        assert table_bytes + tile_bytes < 16 * 1024 * 1024
